//! Opt-in op-level tracing for the native backend.
//!
//! When armed (CLI `--trace-ops true` / `FITQ_TRACE_OPS`), every op the
//! interpreter dispatches records one [`OpRecord`] — op kind, layer,
//! shape, chosen kernel variant, f32 elements moved, a nominal FLOP
//! count, and monotonic wall time — accumulated in place into
//! per-(op, layer, variant) [`OpAggregate`] rows. When disarmed (the
//! default), the whole layer is one predictable `Option` branch per op:
//! no clock reads, no allocation, no locks ([`tests/perf_probes.rs`]
//! enforces the overhead stays in the noise band).
//!
//! # Determinism contract
//!
//! Tracing observes; it never participates. Every counter except
//! `wall_ns` is a pure function of the workload (op counts, element
//! counts, FLOPs, routed variants are identical across runs, `--jobs`
//! settings and thread budgets), and traced runs are bit-identical to
//! untraced runs — losses, gradients, and every pipeline stage digest
//! (`tests/op_trace.rs` pins both). For byte comparisons,
//! [`OpTraceReport::normalized`] zeroes the single nondeterministic
//! field, following the `iter_time_s` convention of the study codec.
//!
//! Aggregates persist through the artifact cache as kind
//! [`OPTRACE_KIND`] (`coordinator/pipeline/codec.rs`, schema
//! `OPTRACE_SCHEMA`) and render into a cost report via
//! `coordinator::analysis` / `fitq trace-report`. The trace key
//! (`stages::optrace_key`) deliberately excludes tracing state itself —
//! profiling never changes results, so it must never split a digest.
//!
//! The `FITQ_NATIVE_REFERENCE` scalar-oracle path is deliberately
//! untraced: it bypasses kernel routing, so it has no variant identity
//! to record.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use super::simd::Isa;
use super::tune::Lowering;

/// Artifact-cache kind of persisted op traces.
pub const OPTRACE_KIND: &str = "optrace";

/// Every op kind the profiler distinguishes. Discriminants are
/// persisted by the `optrace` codec; the first five match
/// [`super::tune::TunedOp`] order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracedOp {
    ConvFwd = 0,
    ConvBwdW = 1,
    ConvBwdX = 2,
    DenseFwd = 3,
    DenseBwd = 4,
    Relu = 5,
    ReluBwd = 6,
    MaxPool = 7,
    MaxPoolBwd = 8,
    BatchNorm = 9,
    BatchNormBwd = 10,
    SoftmaxXent = 11,
    SoftmaxXentBwd = 12,
    AdamStep = 13,
}

/// All traced ops, in discriminant order.
pub const TRACED_OPS: [TracedOp; 14] = [
    TracedOp::ConvFwd,
    TracedOp::ConvBwdW,
    TracedOp::ConvBwdX,
    TracedOp::DenseFwd,
    TracedOp::DenseBwd,
    TracedOp::Relu,
    TracedOp::ReluBwd,
    TracedOp::MaxPool,
    TracedOp::MaxPoolBwd,
    TracedOp::BatchNorm,
    TracedOp::BatchNormBwd,
    TracedOp::SoftmaxXent,
    TracedOp::SoftmaxXentBwd,
    TracedOp::AdamStep,
];

impl TracedOp {
    /// Stable name (report tables, JSON output).
    pub fn name(self) -> &'static str {
        match self {
            TracedOp::ConvFwd => "conv_fwd",
            TracedOp::ConvBwdW => "conv_bwd_w",
            TracedOp::ConvBwdX => "conv_bwd_x",
            TracedOp::DenseFwd => "dense_fwd",
            TracedOp::DenseBwd => "dense_bwd",
            TracedOp::Relu => "relu",
            TracedOp::ReluBwd => "relu_bwd",
            TracedOp::MaxPool => "max_pool",
            TracedOp::MaxPoolBwd => "max_pool_bwd",
            TracedOp::BatchNorm => "batch_norm",
            TracedOp::BatchNormBwd => "batch_norm_bwd",
            TracedOp::SoftmaxXent => "softmax_xent",
            TracedOp::SoftmaxXentBwd => "softmax_xent_bwd",
            TracedOp::AdamStep => "adam_step",
        }
    }

    /// Inverse of the persisted discriminant; `None` for unknown tags
    /// (the decoder fails closed on them).
    pub fn from_u8(v: u8) -> Option<TracedOp> {
        TRACED_OPS.into_iter().find(|op| *op as u8 == v)
    }
}

/// Where in the network an op ran. Kept as a `Copy` enum so setting it
/// from the interpreter allocates nothing; rendered to the report's
/// layer string only at aggregation time (the armed path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Layer {
    /// Outside any labeled region (should not appear in real traces).
    #[default]
    None,
    /// Conv stage `i` (forward or backward).
    Conv(u8),
    /// The dense head.
    Fc,
    /// The softmax/cross-entropy loss block.
    Loss,
    /// The optimizer update.
    Opt,
}

impl Layer {
    /// Report-facing name (`conv0`, `fc`, `loss`, `opt`).
    pub fn name(self) -> String {
        match self {
            Layer::None => "-".to_string(),
            Layer::Conv(i) => format!("conv{i}"),
            Layer::Fc => "fc".to_string(),
            Layer::Loss => "loss".to_string(),
            Layer::Opt => "opt".to_string(),
        }
    }
}

/// One op invocation, as handed to [`Prof::record`]. Constructed lazily
/// (inside a closure) so the disarmed path never formats shapes or
/// counts elements.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub op: TracedOp,
    /// Routed kernel variant for tuned ops; `None` for elementwise ops
    /// that have a single implementation.
    pub variant: Option<(Isa, Lowering)>,
    /// The op's tuning-axis width (`c_out`, `c_in`, `f_out`); 0 for
    /// untuned ops. Feeds the `fitq tune` routing trailer.
    pub width: u32,
    /// Human-readable problem shape, e.g. `b32 16x16 8->16`.
    pub shape: String,
    /// f32 elements read (logical operands, not cache traffic).
    pub elems_read: u64,
    /// f32 elements written.
    pub elems_written: u64,
    /// Nominal FLOPs (same conventions as the autotuner's GFLOP/s).
    pub flops: u64,
}

/// Per-(op, layer, variant) accumulated counters — one report row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpAggregate {
    pub op: TracedOp,
    /// Rendered [`Layer`] name.
    pub layer: String,
    pub variant: Option<(Isa, Lowering)>,
    pub width: u32,
    /// Shape of the first recorded invocation (within one aggregate key
    /// the shape is fixed by the model, so first == all).
    pub shape: String,
    pub calls: u64,
    pub elems_read: u64,
    pub elems_written: u64,
    pub flops: u64,
    /// Total monotonic wall time — the only nondeterministic field in a
    /// trace; [`OpTraceReport::normalized`] zeroes it.
    pub wall_ns: u64,
}

impl OpAggregate {
    /// `lowering/isa` (the BENCH_kernels.json route format), `-` for
    /// untuned ops.
    pub fn variant_name(&self) -> String {
        match self.variant {
            Some((isa, lowering)) => format!("{}/{}", lowering.name(), isa.name()),
            None => "-".to_string(),
        }
    }
}

/// A complete op trace: the aggregate rows plus the identity of the run
/// that produced them. This is what the `optrace` codec persists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpTraceReport {
    /// Model name (fills the cache key via `stages::optrace_key`).
    pub model: String,
    /// Workload label, e.g. `train_epoch`.
    pub workload: String,
    /// Intra-op thread budget the run executed under (recorded for the
    /// report header; never part of the trace key).
    pub threads: u32,
    /// Aggregate rows in first-recorded order (deterministic: the
    /// interpreter's op order is fixed).
    pub rows: Vec<OpAggregate>,
}

impl OpTraceReport {
    /// The report with every wall-clock counter zeroed — byte-stable
    /// across equivalent runs (the `study_bytes` convention of
    /// `tests/zoo_models.rs`).
    pub fn normalized(&self) -> OpTraceReport {
        let mut r = self.clone();
        for row in &mut r.rows {
            row.wall_ns = 0;
        }
        r
    }

    /// Total wall time across all rows.
    pub fn total_wall_ns(&self) -> u64 {
        self.rows.iter().map(|r| r.wall_ns).sum()
    }
}

#[derive(Debug, Default)]
struct ProfState {
    layer: Layer,
    rows: Vec<OpAggregate>,
}

impl ProfState {
    fn record(&mut self, r: OpRecord, wall_ns: u64) {
        let layer = self.layer.name();
        // Linear scan: a study net produces ~20 aggregate keys, and
        // insertion order keeps the report deterministic.
        for agg in &mut self.rows {
            if agg.op == r.op && agg.variant == r.variant && agg.layer == layer {
                agg.calls += 1;
                agg.elems_read += r.elems_read;
                agg.elems_written += r.elems_written;
                agg.flops += r.flops;
                agg.wall_ns += wall_ns;
                return;
            }
        }
        self.rows.push(OpAggregate {
            op: r.op,
            layer,
            variant: r.variant,
            width: r.width,
            shape: r.shape,
            calls: 1,
            elems_read: r.elems_read,
            elems_written: r.elems_written,
            flops: r.flops,
            wall_ns,
        });
    }
}

/// Cloneable handle to an optional profiler. `Prof::default()` is
/// disarmed and free: every entry point is a single `Option` branch.
/// Armed handles share one accumulator (`Rc` — the native backend and
/// its dispatchers are single-threaded by construction, like
/// `Runtime`).
#[derive(Debug, Clone, Default)]
pub struct Prof(Option<Rc<RefCell<ProfState>>>);

impl Prof {
    /// An armed profiler with an empty accumulator.
    pub fn armed() -> Prof {
        Prof(Some(Rc::new(RefCell::new(ProfState::default()))))
    }

    /// Whether records are being collected.
    pub fn is_armed(&self) -> bool {
        self.0.is_some()
    }

    /// Start timing an op: `None` (no clock read) when disarmed.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.0.is_some() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Finish timing and record; `make` only runs when armed, so the
    /// disarmed path never formats or counts.
    #[inline]
    pub fn record(&self, start: Option<Instant>, make: impl FnOnce() -> OpRecord) {
        let (Some(state), Some(t0)) = (self.0.as_ref(), start) else { return };
        let wall_ns = t0.elapsed().as_nanos() as u64;
        state.borrow_mut().record(make(), wall_ns);
    }

    /// One-line recording of an untuned (elementwise) op.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn record_untuned(
        &self,
        start: Option<Instant>,
        op: TracedOp,
        elems_read: usize,
        elems_written: usize,
        flops: usize,
        shape: impl FnOnce() -> String,
    ) {
        self.record(start, || OpRecord {
            op,
            variant: None,
            width: 0,
            shape: shape(),
            elems_read: elems_read as u64,
            elems_written: elems_written as u64,
            flops: flops as u64,
        });
    }

    /// Label the current network region; a no-op when disarmed.
    #[inline]
    pub fn set_layer(&self, layer: Layer) {
        if let Some(state) = self.0.as_ref() {
            state.borrow_mut().layer = layer;
        }
    }

    /// Snapshot the aggregate rows collected so far (armed handles
    /// only). Rows stay accumulated — a snapshot observes, it does not
    /// drain.
    pub fn snapshot(&self) -> Option<Vec<OpAggregate>> {
        self.0.as_ref().map(|state| state.borrow().rows.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(op: TracedOp, shape: &str) -> OpRecord {
        OpRecord {
            op,
            variant: None,
            width: 0,
            shape: shape.to_string(),
            elems_read: 10,
            elems_written: 5,
            flops: 100,
        }
    }

    #[test]
    fn disarmed_prof_collects_nothing() {
        let p = Prof::default();
        assert!(!p.is_armed());
        assert_eq!(p.start(), None, "disarmed start must not read the clock");
        p.record(p.start(), || panic!("record closure must not run disarmed"));
        p.set_layer(Layer::Fc);
        assert!(p.snapshot().is_none());
    }

    #[test]
    fn armed_prof_aggregates_by_op_layer_variant() {
        let p = Prof::armed();
        p.set_layer(Layer::Conv(0));
        p.record(p.start(), || rec(TracedOp::Relu, "256"));
        p.record(p.start(), || rec(TracedOp::Relu, "256"));
        p.set_layer(Layer::Conv(1));
        p.record(p.start(), || rec(TracedOp::Relu, "128"));
        let rows = p.snapshot().unwrap();
        assert_eq!(rows.len(), 2, "same (op, layer, variant) must merge");
        assert_eq!(rows[0].layer, "conv0");
        assert_eq!(rows[0].calls, 2);
        assert_eq!(rows[0].elems_read, 20);
        assert_eq!(rows[0].flops, 200);
        assert_eq!(rows[1].layer, "conv1");
        assert_eq!(rows[1].calls, 1);
        assert_eq!(rows[1].shape, "128", "first-seen shape is kept");
    }

    #[test]
    fn clones_share_one_accumulator() {
        let p = Prof::armed();
        let q = p.clone();
        p.set_layer(Layer::Opt);
        q.record(q.start(), || rec(TracedOp::AdamStep, "6138"));
        assert_eq!(p.snapshot().unwrap().len(), 1, "clone records into the shared state");
    }

    #[test]
    fn normalized_zeroes_only_wall_clock() {
        let p = Prof::armed();
        p.set_layer(Layer::Loss);
        p.record(p.start(), || rec(TracedOp::SoftmaxXent, "32x10"));
        let report = OpTraceReport {
            model: "m".into(),
            workload: "w".into(),
            threads: 1,
            rows: p.snapshot().unwrap(),
        };
        let norm = report.normalized();
        assert!(norm.rows.iter().all(|r| r.wall_ns == 0));
        let mut a = report.clone();
        for row in &mut a.rows {
            row.wall_ns = 0;
        }
        assert_eq!(a, norm, "normalization touches nothing but wall_ns");
    }

    #[test]
    fn traced_op_tags_round_trip_and_unknowns_fail() {
        for op in TRACED_OPS {
            assert_eq!(TracedOp::from_u8(op as u8), Some(op));
            assert!(!op.name().is_empty());
        }
        assert_eq!(TracedOp::from_u8(200), None);
    }

    #[test]
    fn layer_names_are_stable() {
        assert_eq!(Layer::Conv(2).name(), "conv2");
        assert_eq!(Layer::Fc.name(), "fc");
        assert_eq!(Layer::Loss.name(), "loss");
        assert_eq!(Layer::Opt.name(), "opt");
        assert_eq!(Layer::None.name(), "-");
    }
}
