//! Native model zoo: the study CNNs, their flat parameter layout, and
//! the generated manifest.
//!
//! This is the Rust twin of `python/compile/model.py::build_cnn` +
//! `aot.py::build_entries` for the Table-2 study models: the same tensor
//! order (`convI.w`, `convI.b`, [`convI.gamma`, `convI.beta`,] …, `fc.w`,
//! `fc.b`), the same quantizable-block indexing, the same activation
//! sites, and entry-point IoSpecs matching what aot.py lowers — so the
//! coordinator cannot tell the backends apart structurally. Numeric
//! outputs are *not* expected to match PJRT bit-for-bit (different
//! init RNG, different summation orders); backend identity is part of
//! every pipeline cache key for exactly that reason.

use std::collections::BTreeMap;

use crate::runtime::artifact::{
    ActBlock, DType, EntrySpec, IoSpec, ModelManifest, Task, TensorInfo, WeightBlock,
};
use crate::tensor::Pcg32;

/// Microbatch steps per train/qat dispatch (aot.py TRAIN_K).
pub const TRAIN_K: usize = 10;
/// Train microbatch size (aot.py TRAIN_B).
pub const TRAIN_B: usize = 32;
/// Masked-evaluation batch size (aot.py EVAL_B).
pub const EVAL_B: usize = 256;
/// Activation-range calibration batch size (aot.py CALIB_B).
pub const CALIB_B: usize = 128;
/// Predict-entry batch size (aot.py PREDICT_B).
pub const PREDICT_B: usize = 32;
/// EF-trace batch sizes lowered for study models (aot.py STUDY_TRACE_BS).
pub const TRACE_BS: &[usize] = &[32];

/// FP-training Adam learning rate (train.py ADAM; study models have no
/// per-model overrides).
pub const FP_LR: f32 = 1e-2;
/// QAT fine-tune Adam learning rate (train.py QAT_ADAM).
pub const QAT_LR: f32 = 1e-3;

/// Stream-seed salt for the He-normal init RNG (one `Pcg32` per tensor).
pub const INIT_SALT: u64 = 0x1A17_5EED;

/// A study CNN: Fig. 8 architecture family (model.py CNNConfig).
#[derive(Debug, Clone, Copy)]
pub struct CnnSpec {
    pub name: &'static str,
    /// (H, W, C) input shape.
    pub input: (usize, usize, usize),
    /// One conv layer per entry (3x3, SAME, stride 1).
    pub filters: &'static [usize],
    pub n_classes: usize,
    pub batch_norm: bool,
    /// 2x2 max-pool after conv `i` (0-based).
    pub pool_after: &'static [usize],
}

impl CnnSpec {
    /// Desugar the static table entry into the owned [`ModelSpec`] the
    /// plan builder consumes (table-wide `batch_norm` becomes per-layer).
    pub fn to_model_spec(&self) -> ModelSpec {
        ModelSpec {
            name: self.name.to_string(),
            input: self.input,
            convs: self
                .filters
                .iter()
                .enumerate()
                .map(|(i, &c_out)| ConvSpec {
                    c_out,
                    batch_norm: self.batch_norm,
                    pooled: self.pool_after.contains(&i),
                })
                .collect(),
            n_classes: self.n_classes,
        }
    }
}

/// One conv stage of a [`ModelSpec`]: output width, normalization and
/// pooling — everything the interpreter needs beyond the running shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    /// Output channels of the 3x3 SAME stride-1 convolution.
    pub c_out: usize,
    /// Insert batch-norm between bias and relu for this layer.
    pub batch_norm: bool,
    /// 2x2 max-pool after this layer's relu.
    pub pooled: bool,
}

/// An owned model description — the single input of [`Plan::from_spec`].
///
/// Builtin [`CnnSpec`] table entries desugar into this via
/// [`CnnSpec::to_model_spec`], and `native::manifest` compiles validated
/// zoo manifests into it, so both construction paths share one plan
/// builder. Unlike `CnnSpec`, batch-norm is a per-layer property.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    /// (H, W, C) input shape.
    pub input: (usize, usize, usize),
    /// Conv stages in execution order; the dense head follows.
    pub convs: Vec<ConvSpec>,
    pub n_classes: usize,
}

/// The Table-2 study models the native backend implements.
pub const STUDY_CNNS: &[CnnSpec] = &[
    CnnSpec {
        name: "cnn_mnist",
        input: (16, 16, 1),
        filters: &[8, 16, 16],
        n_classes: 10,
        batch_norm: false,
        pool_after: &[0, 1],
    },
    CnnSpec {
        name: "cnn_mnist_bn",
        input: (16, 16, 1),
        filters: &[8, 16, 16],
        n_classes: 10,
        batch_norm: true,
        pool_after: &[0, 1],
    },
    CnnSpec {
        name: "cnn_cifar",
        input: (32, 32, 3),
        filters: &[16, 32, 32],
        n_classes: 10,
        batch_norm: false,
        pool_after: &[0, 1],
    },
    CnnSpec {
        name: "cnn_cifar_bn",
        input: (32, 32, 3),
        filters: &[16, 32, 32],
        n_classes: 10,
        batch_norm: true,
        pool_after: &[0, 1],
    },
];

/// One conv layer's geometry + parameter offsets inside the flat vector.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    /// Input spatial dims (post previous pool).
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub w_off: usize,
    pub b_off: usize,
    /// BN scale/shift offsets (models with `batch_norm`).
    pub gamma_off: Option<usize>,
    pub beta_off: Option<usize>,
    /// 2x2 max-pool after this layer.
    pub pooled: bool,
}

impl ConvLayer {
    /// Elements of the HWIO kernel.
    pub fn w_size(&self) -> usize {
        9 * self.c_in * self.c_out
    }

    /// Per-sample output (= activation-site) element count.
    pub fn act_size(&self) -> usize {
        self.h * self.w * self.c_out
    }

    /// GEMM reduction depth of this layer's im2col lowering (`9 * c_in`
    /// — one column per `(di, dj, ci)` tap; see `native::gemm`).
    pub fn gemm_k(&self) -> usize {
        9 * self.c_in
    }

    /// GEMM row count of this layer for a batch (= output pixels; the
    /// axis the M-panel fan-out splits).
    pub fn gemm_m(&self, batch: usize) -> usize {
        batch * self.h * self.w
    }
}

/// The interpreter's execution plan for one model: geometry, offsets and
/// the generated [`ModelManifest`].
#[derive(Debug)]
pub struct Plan {
    pub spec: ModelSpec,
    pub convs: Vec<ConvLayer>,
    pub fc_w_off: usize,
    pub fc_b_off: usize,
    /// Flattened feature dim entering the fc layer.
    pub feat: usize,
    pub n_params: usize,
    tensors: Vec<TensorInfo>,
}

fn tensor(name: String, shape: Vec<usize>, offset: usize, kind: &str, block: i64) -> TensorInfo {
    let size = shape.iter().product();
    TensorInfo { name, shape, offset, size, kind: kind.to_string(), block }
}

impl Plan {
    /// Build the execution plan for one study CNN table entry — the
    /// historical constructor, now a [`CnnSpec`] desugaring over
    /// [`Plan::from_spec`].
    pub fn new(spec: CnnSpec) -> Plan {
        Plan::from_spec(spec.to_model_spec())
    }

    /// Build the execution plan (geometry, flat offsets, manifest
    /// tensors) from an owned [`ModelSpec`] — the one constructor both
    /// the builtin table and `native::manifest`'s compiled zoo models
    /// flow through. Tensor naming stays positional (`convI.w`, `fc.w`),
    /// independent of any manifest layer names, so an equivalent zoo
    /// manifest reproduces the builtin layout bit-for-bit.
    pub fn from_spec(spec: ModelSpec) -> Plan {
        let (mut h, mut w) = (spec.input.0, spec.input.1);
        let mut c_in = spec.input.2;
        let mut off = 0usize;
        let mut convs = Vec::new();
        let mut tensors = Vec::new();
        let mut block = 0i64;
        for (i, cs) in spec.convs.iter().enumerate() {
            let c_out = cs.c_out;
            let w_off = off;
            let w_shape = vec![3, 3, c_in, c_out];
            tensors.push(tensor(format!("conv{i}.w"), w_shape, off, "conv_w", block));
            block += 1;
            off += 9 * c_in * c_out;
            let b_off = off;
            tensors.push(tensor(format!("conv{i}.b"), vec![c_out], off, "bias", -1));
            off += c_out;
            let (mut gamma_off, mut beta_off) = (None, None);
            if cs.batch_norm {
                gamma_off = Some(off);
                tensors.push(tensor(format!("conv{i}.gamma"), vec![c_out], off, "bn_gamma", -1));
                off += c_out;
                beta_off = Some(off);
                tensors.push(tensor(format!("conv{i}.beta"), vec![c_out], off, "bn_beta", -1));
                off += c_out;
            }
            let pooled = cs.pooled;
            convs.push(ConvLayer { h, w, c_in, c_out, w_off, b_off, gamma_off, beta_off, pooled });
            if pooled {
                h /= 2;
                w /= 2;
            }
            c_in = c_out;
        }
        let feat = h * w * c_in;
        let fc_w_off = off;
        tensors.push(tensor("fc.w".into(), vec![feat, spec.n_classes], off, "fc_w", block));
        off += feat * spec.n_classes;
        let fc_b_off = off;
        tensors.push(tensor("fc.b".into(), vec![spec.n_classes], off, "bias", -1));
        off += spec.n_classes;
        Plan { spec, convs, fc_w_off, fc_b_off, feat, n_params: off, tensors }
    }

    /// Quantizable weight blocks (one per conv kernel, plus fc).
    pub fn n_weight_blocks(&self) -> usize {
        self.convs.len() + 1
    }

    /// Quantizable activation sites (one per conv layer's post-relu).
    pub fn n_act_blocks(&self) -> usize {
        self.convs.len()
    }

    /// Per-sample input element count.
    pub fn sample_len(&self) -> usize {
        self.spec.input.0 * self.spec.input.1 * self.spec.input.2
    }

    /// (offset, size) of quantizable weight block `l` (convs, then fc).
    pub fn weight_block(&self, l: usize) -> (usize, usize) {
        if l < self.convs.len() {
            (self.convs[l].w_off, self.convs[l].w_size())
        } else {
            (self.fc_w_off, self.feat * self.spec.n_classes)
        }
    }

    /// He-normal init from a u32 seed: one RNG per tensor (seed, salt,
    /// tensor index), std = sqrt(2 / fan_in); unit gammas, zero biases —
    /// the native twin of layers.py `init_flat` (different RNG family, so
    /// native and PJRT checkpoints are numerically independent).
    pub fn init_flat(&self, seed: u32) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_params];
        for (i, t) in self.tensors.iter().enumerate() {
            match t.kind.as_str() {
                "conv_w" | "fc_w" => {
                    let fan_in: usize = if t.kind == "conv_w" {
                        t.shape[0] * t.shape[1] * t.shape[2]
                    } else {
                        t.shape[0]
                    };
                    let std = (2.0 / fan_in as f64).sqrt() as f32;
                    let mut rng = Pcg32::new(seed as u64 ^ INIT_SALT, i as u64 + 1);
                    for v in &mut out[t.offset..t.offset + t.size] {
                        *v = rng.normal() * std;
                    }
                }
                "bn_gamma" => out[t.offset..t.offset + t.size].fill(1.0),
                _ => {}
            }
        }
        out
    }

    /// The generated manifest entry for this model — structurally
    /// identical to what aot.py writes for the same model.
    pub fn manifest(&self) -> ModelManifest {
        let spec = &self.spec;
        let weight_blocks = (0..self.n_weight_blocks())
            .map(|l| {
                let (offset, size) = self.weight_block(l);
                let (name, shape) = if l < self.convs.len() {
                    let c = &self.convs[l];
                    (format!("conv{l}.w"), vec![3, 3, c.c_in, c.c_out])
                } else {
                    ("fc.w".to_string(), vec![self.feat, spec.n_classes])
                };
                WeightBlock { index: l, name, offset, size, shape }
            })
            .collect();
        let act_blocks = self
            .convs
            .iter()
            .enumerate()
            .map(|(i, c)| ActBlock {
                index: i,
                shape: vec![c.h, c.w, c.c_out],
                size: c.act_size(),
            })
            .collect();
        ModelManifest {
            name: spec.name.clone(),
            n_params: self.n_params,
            input_shape: vec![spec.input.0, spec.input.1, spec.input.2],
            n_classes: spec.n_classes,
            task: Task::Classify,
            train_k: TRAIN_K,
            train_b: TRAIN_B,
            eval_b: EVAL_B,
            calib_b: CALIB_B,
            predict_b: PREDICT_B,
            trace_bs: TRACE_BS.to_vec(),
            weight_blocks,
            act_blocks,
            tensors: self.tensors.clone(),
            entries: self.entries(),
        }
    }

    /// Entry-point IoSpecs, mirroring aot.py `build_entries` for a study
    /// model (`hutch_*` is a scale-model entry and has no native twin).
    fn entries(&self) -> BTreeMap<String, EntrySpec> {
        let spec = &self.spec;
        let n = self.n_params;
        let (h, w, c) = spec.input;
        let (lw, la) = (self.n_weight_blocks(), self.n_act_blocks());
        let f32v = |name: &str, shape: Vec<usize>| IoSpec {
            name: name.to_string(),
            shape,
            dtype: DType::F32,
        };
        let i32v = |name: &str, shape: Vec<usize>| IoSpec {
            name: name.to_string(),
            shape,
            dtype: DType::I32,
        };
        let state_in = |k: usize, b: usize| {
            vec![
                f32v("params", vec![n]),
                f32v("m", vec![n]),
                f32v("v", vec![n]),
                f32v("step", vec![]),
                f32v("xs", vec![k, b, h, w, c]),
                i32v("ys", vec![k, b]),
            ]
        };
        let state_out = vec![
            f32v("params", vec![n]),
            f32v("m", vec![n]),
            f32v("v", vec![n]),
            f32v("step", vec![]),
            f32v("loss", vec![]),
        ];
        let quant_in = vec![
            f32v("bits_w", vec![lw]),
            f32v("bits_a", vec![la]),
            f32v("act_lo", vec![la]),
            f32v("act_hi", vec![la]),
        ];
        let eval_in = vec![
            f32v("params", vec![n]),
            f32v("x", vec![EVAL_B, h, w, c]),
            i32v("y", vec![EVAL_B]),
            f32v("mask", vec![EVAL_B]),
        ];
        let eval_out =
            vec![f32v("loss_sum", vec![]), f32v("correct", vec![]), f32v("n", vec![])];

        let mut entries = BTreeMap::new();
        let mut add = |name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| {
            entries.insert(
                name.to_string(),
                EntrySpec {
                    name: name.to_string(),
                    file: format!("native://{}/{name}", spec.name),
                    inputs,
                    outputs,
                },
            );
        };
        add(
            "init",
            vec![IoSpec { name: "seed".into(), shape: vec![], dtype: DType::U32 }],
            vec![f32v("params", vec![n])],
        );
        add("train_epoch", state_in(TRAIN_K, TRAIN_B), state_out.clone());
        if spec.name == "cnn_mnist" {
            // K=1 variant kept for the §Perf scan-amortization probe.
            add("train_step", state_in(1, TRAIN_B), state_out.clone());
        }
        add(
            "qat_epoch",
            [state_in(TRAIN_K, TRAIN_B), quant_in.clone()].concat(),
            state_out,
        );
        add("eval", eval_in.clone(), eval_out.clone());
        add("qat_eval", [eval_in, quant_in].concat(), eval_out);
        add(
            "predict",
            vec![f32v("params", vec![n]), f32v("x", vec![PREDICT_B, h, w, c])],
            vec![f32v("logits", vec![PREDICT_B, spec.n_classes])],
        );
        add(
            "param_ranges",
            vec![f32v("params", vec![n])],
            vec![f32v("lo", vec![lw]), f32v("hi", vec![lw])],
        );
        add(
            "act_ranges",
            vec![f32v("params", vec![n]), f32v("x", vec![CALIB_B, h, w, c])],
            vec![f32v("lo", vec![la]), f32v("hi", vec![la])],
        );
        for &b in TRACE_BS {
            add(
                &format!("ef_trace_bs{b}"),
                vec![f32v("params", vec![n]), f32v("x", vec![b, h, w, c]), i32v("y", vec![b])],
                vec![f32v("w_tr", vec![lw]), f32v("a_tr", vec![la])],
            );
        }
        entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist_plan() -> Plan {
        Plan::new(STUDY_CNNS[0])
    }

    #[test]
    fn layout_matches_python_reference() {
        // counts cross-checked against model.py build_cnn (and the JAX
        // parity run recorded in the PR that introduced this backend)
        let p = mnist_plan();
        assert_eq!(p.n_params, 6138);
        assert_eq!(p.n_weight_blocks(), 4);
        assert_eq!(p.n_act_blocks(), 3);
        assert_eq!(p.feat, 256);
        assert_eq!(p.weight_block(0), (0, 72));
        assert_eq!(p.weight_block(3), (p.fc_w_off, 2560));
        let bn = Plan::new(STUDY_CNNS[1]);
        assert_eq!(bn.n_params, 6138 + 2 * (8 + 16 + 16));
    }

    #[test]
    fn layout_covers_whole_vector_in_order() {
        for spec in STUDY_CNNS {
            let p = Plan::new(*spec);
            let mut off = 0;
            for t in &p.tensors {
                assert_eq!(t.offset, off, "{}: {}", spec.name, t.name);
                off += t.size;
            }
            assert_eq!(off, p.n_params, "{}", spec.name);
        }
    }

    #[test]
    fn manifest_is_structurally_consistent() {
        for spec in STUDY_CNNS {
            let p = Plan::new(*spec);
            let m = p.manifest();
            assert_eq!(m.n_params, p.n_params);
            assert_eq!(m.tensors.iter().map(|t| t.size).sum::<usize>(), m.n_params);
            assert_eq!(m.n_weight_blocks(), p.n_weight_blocks());
            assert_eq!(m.n_act_blocks(), p.n_act_blocks());
            // BN naming convention holds (bn_gamma_views finds the scales)
            let views = m.bn_gamma_views();
            if spec.batch_norm {
                assert!(views[..views.len() - 1].iter().all(|v| v.is_some()), "{}", spec.name);
                assert!(views[views.len() - 1].is_none(), "fc has no BN");
            } else {
                assert!(views.iter().all(|v| v.is_none()));
            }
            // every entry's IoSpecs have consistent element counts
            let e = m.entry("ef_trace_bs32").unwrap();
            assert_eq!(e.outputs[0].shape, vec![m.n_weight_blocks()]);
            assert_eq!(e.inputs[1].numel(), 32 * p.sample_len());
            let t = m.entry("train_epoch").unwrap();
            assert_eq!(t.inputs[4].numel(), TRAIN_K * TRAIN_B * p.sample_len());
            assert_eq!(t.outputs[3].numel(), 1, "step is a scalar");
        }
    }

    #[test]
    fn train_step_only_on_cnn_mnist() {
        assert!(Plan::new(STUDY_CNNS[0]).manifest().entry("train_step").is_ok());
        assert!(Plan::new(STUDY_CNNS[1]).manifest().entry("train_step").is_err());
    }

    #[test]
    fn model_spec_desugaring_matches_the_table() {
        for spec in STUDY_CNNS {
            let a = Plan::new(*spec);
            let b = Plan::from_spec(spec.to_model_spec());
            assert_eq!(a.n_params, b.n_params, "{}", spec.name);
            assert_eq!(a.spec, b.spec, "{}", spec.name);
            assert_eq!(a.init_flat(3), b.init_flat(3), "{}", spec.name);
        }
    }

    #[test]
    fn per_layer_batch_norm_is_expressible() {
        // beyond the CnnSpec vocabulary: BN on only the first conv
        let p = Plan::from_spec(ModelSpec {
            name: "mixed".into(),
            input: (8, 8, 1),
            convs: vec![
                ConvSpec { c_out: 4, batch_norm: true, pooled: true },
                ConvSpec { c_out: 4, batch_norm: false, pooled: false },
            ],
            n_classes: 3,
        });
        assert!(p.convs[0].gamma_off.is_some());
        assert!(p.convs[1].gamma_off.is_none());
        let views = p.manifest().bn_gamma_views();
        assert!(views[0].is_some());
        assert!(views[1].is_none() && views[2].is_none());
        let f = p.init_flat(1);
        let g = p.convs[0].gamma_off.unwrap();
        assert!(f[g..g + 4].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn init_is_deterministic_seed_sensitive_and_he_scaled() {
        let p = mnist_plan();
        let a = p.init_flat(7);
        let b = p.init_flat(7);
        let c = p.init_flat(8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // conv0: fan_in 9 -> std sqrt(2/9) ~ 0.471
        let w0: Vec<f32> = a[0..72].to_vec();
        let var = w0.iter().map(|x| (x * x) as f64).sum::<f64>() / 72.0;
        assert!((var.sqrt() - (2.0f64 / 9.0).sqrt()).abs() < 0.2, "std {}", var.sqrt());
        // biases zero
        assert!(a[72..80].iter().all(|&x| x == 0.0));
        // BN model: gammas one
        let bn = Plan::new(STUDY_CNNS[1]);
        let f = bn.init_flat(1);
        let g_off = bn.convs[0].gamma_off.unwrap();
        assert!(f[g_off..g_off + 8].iter().all(|&x| x == 1.0));
    }
}
