//! Fake quantization, bit-faithful to the L1 Pallas kernel.
//!
//! The reference semantics (`python/compile/kernels/fake_quant.py`):
//!
//! ```text
//! levels = exp2(bits) - 1
//! ok     = (hi > lo) & (levels >= 1)
//! delta  = ok ? (hi - lo) / max(levels, 1) : 1
//! q      = round((clip(x, lo, hi) - lo) / delta)      // ties to even
//! out    = ok ? q * delta + lo : x                    // fused mul-add
//! ```
//!
//! Two details matter for bit-parity with the compiled kernel (verified
//! against the Pallas oracle during this backend's bring-up):
//! `jnp.round` rounds ties to even (Rust's `f32::round` rounds away from
//! zero), and XLA emits an FMA for `q * delta + lo` — so this module uses
//! [`round_ties_even`] and `f32::mul_add`.
//!
//! The straight-through estimator (model.py `_ste_fake_quant`) is a
//! backward rule, not a function: the quantized forward is piecewise
//! constant, and the STE passes the upstream gradient through unchanged
//! (zeros to `lo`/`hi`/`bits`). In the interpreter that means backward
//! passes simply *skip* the quantization node — there is no code to run,
//! which `tests/native_backend.rs` pins as the STE-identity property.

/// Round to nearest, ties to even (`jnp.round` semantics). Exact for the
/// quantization-index domain (|x| well below 2^23).
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // ties away from zero
    if (x - x.trunc()).abs() == 0.5 && (r as i64) % 2 != 0 {
        r - x.signum()
    } else {
        r
    }
}

/// Quantize-dequantize one value (callers hoist the per-tensor `delta`).
#[inline]
fn fq(x: f32, lo: f32, hi: f32, delta: f32) -> f32 {
    let q = round_ties_even((x.clamp(lo, hi) - lo) / delta);
    q.mul_add(delta, lo)
}

/// The kernel's `(ok, delta)` preamble for a `(lo, hi, bits)` triple.
fn params(lo: f32, hi: f32, bits: f32) -> Option<f32> {
    let levels = bits.exp2() - 1.0;
    if hi > lo && levels >= 1.0 {
        Some((hi - lo) / levels.max(1.0))
    } else {
        None // degenerate range or <1 level: pass through
    }
}

/// Quantize-dequantize `xs` into `out` with a fixed calibrated range.
pub fn fake_quant(xs: &[f32], lo: f32, hi: f32, bits: f32, out: &mut [f32]) {
    match params(lo, hi, bits) {
        Some(delta) => {
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = fq(x, lo, hi, delta);
            }
        }
        None => out.copy_from_slice(xs),
    }
}

/// Weight-tensor fake quant: min-max range computed from the tensor
/// itself (model.py `ste_quant_weight`).
pub fn fake_quant_minmax(xs: &[f32], bits: f32, out: &mut [f32]) {
    let (lo, hi) = match crate::tensor::min_max(xs) {
        Some(r) => r,
        None => return,
    };
    fake_quant(xs, lo, hi, bits, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_ties_even_matches_jnp() {
        for (x, want) in [
            (0.5, 0.0),
            (1.5, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (4.5, 4.0),
            (-0.5, -0.0),
            (-1.5, -2.0),
            (-2.5, -2.0),
            (0.49999, 0.0),
            (2.51, 3.0),
            (7.0, 7.0),
        ] {
            assert_eq!(round_ties_even(x), want, "x={x}");
        }
    }

    #[test]
    fn matches_uniform_quantizer_off_ties() {
        // quant::UniformQuantizer is the analysis-side oracle; away from
        // exact .5 index fractions the two agree bit-for-bit except for
        // the FMA's last-ulp advantage — allow 1 ulp.
        let q = crate::quant::UniformQuantizer::new(-1.2, 0.9, 4);
        let mut rng = crate::tensor::Pcg32::new(3, 9);
        let mut out = [0.0f32];
        for _ in 0..2000 {
            let x = rng.uniform_in(-2.0, 2.0);
            fake_quant(&[x], -1.2, 0.9, 4.0, &mut out);
            let want = q.apply(x);
            let ulp = (want.abs().max(1e-6)) * f32::EPSILON * 2.0;
            assert!((out[0] - want).abs() <= ulp, "x={x}: {} vs {want}", out[0]);
        }
    }

    #[test]
    fn endpoints_clip_and_fix() {
        let mut out = [0.0f32; 4];
        fake_quant(&[-5.0, -1.0, 1.0, 5.0], -1.0, 1.0, 8.0, &mut out);
        assert_eq!(out, [-1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn degenerate_range_passes_through() {
        let xs = [3.7f32, -1.0, 0.0];
        let mut out = [0.0f32; 3];
        fake_quant(&xs, 1.0, 1.0, 8.0, &mut out);
        assert_eq!(out, xs);
        // bits = 0 -> levels = 0 -> pass through
        fake_quant(&xs, -1.0, 1.0, 0.0, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn level_count_is_2_pow_b() {
        let mut levels = std::collections::BTreeSet::new();
        let mut out = [0.0f32];
        for i in 0..=1000 {
            let x = -1.0 + 2.0 * i as f32 / 1000.0;
            fake_quant(&[x], -1.0, 1.0, 2.0, &mut out);
            levels.insert(out[0].to_bits());
        }
        assert_eq!(levels.len(), 4);
    }

    #[test]
    fn minmax_keeps_extremes_fixed() {
        let xs = [-0.75f32, 0.1, 0.3, 1.25];
        let mut out = [0.0f32; 4];
        fake_quant_minmax(&xs, 8.0, &mut out);
        assert_eq!(out[0], -0.75);
        assert_eq!(out[3], 1.25);
        // idempotent
        let mut out2 = [0.0f32; 4];
        fake_quant_minmax(&out, 8.0, &mut out2);
        for (a, b) in out.iter().zip(&out2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn ties_round_to_even_index() {
        // lo=0, hi=15, bits=4 -> delta = 1: x = k + 0.5 ties to even k
        let xs = [0.5f32, 1.5, 2.5, 3.5, 4.5];
        let mut out = [0.0f32; 5];
        fake_quant(&xs, 0.0, 15.0, 4.0, &mut out);
        assert_eq!(out, [0.0, 2.0, 2.0, 4.0, 4.0]);
    }
}
