//! Runtime-dispatched SIMD panel kernels behind the GEMM layer.
//!
//! Every hot kernel in `gemm.rs` bottoms out in one of six *panel*
//! routines defined here, generated once per instruction set by
//! [`define_panel_kernels!`]: scalar always, SSE2 + AVX2 on x86-64
//! (SSE2 is the baseline rustc already targets; AVX2 is gated on
//! `is_x86_feature_detected!`), NEON on aarch64. The variant to run is
//! picked at dispatch time from an [`Isa`] value the caller threads
//! through — either forced (`FITQ_NATIVE_KERNEL`) or chosen per
//! (op, shape-class) by the autotuner (`native::tune`).
//!
//! # The 0-ULP contract survives vectorization
//!
//! All variants are bit-identical to `ops::reference` because
//! vectorization only ever runs across *independent output elements*
//! (the channel / column axis); the reduction over k (or taps) stays a
//! serial `acc += a * b` per output in the reference order. Two rules
//! make that literal:
//!
//! - **never FMA**: `axpy` uses a separate multiply then add
//!   (`_mm_add_ps(acc, _mm_mul_ps(s, v))`), i.e. the same two
//!   roundings as the scalar `*c += s * v`. A fused `vfmadd`/`vfmaq`
//!   would round once and break bit-identity.
//! - **skip semantics are preserved, not approximated**: the exact-zero
//!   skips (`a == 0.0` in `sgemm`/`sgemm_atb`, `xv == 0.0` in the conv
//!   weight gradient) guard whole `axpy` rows, so the signed-zero
//!   algebra of the remaining adds is untouched. The conv *forward*
//!   has no skip — neither does `ops::reference::conv2d`, and skipping
//!   there would turn `(+0.0) + (-0.0)*w` into `+0.0` vs `-0.0`.
//!
//! Adding an ISA = one `mod` with `axpy`/`vadd` intrinsics + a
//! `define_panel_kernels!` invocation + an [`Isa`] arm; the variant
//! matrix in `tests/native_gemm.rs` then pins it at 0 ULP
//! automatically (it iterates [`Isa::detected`]).

use super::ops::reference::tap_range;

/// One kernel-variant instruction set. Discriminants are stable — they
/// are persisted inside tuner tables (`native::tune`) and folded into
/// the host fingerprint bitmask.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Plain loops — the portable baseline, available everywhere.
    Scalar = 0,
    /// 4-wide `_mm` intrinsics; x86-64 baseline, no runtime gate.
    Sse2 = 1,
    /// 8-wide `_mm256` intrinsics; gated on `is_x86_feature_detected!`.
    Avx2 = 2,
    /// 4-wide `vld1q`/`vst1q` intrinsics; aarch64 baseline.
    Neon = 3,
}

/// All variants this build knows about, ascending by preference.
pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon];

impl Isa {
    /// Stable lowercase name (the `FITQ_NATIVE_KERNEL` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Inverse of [`Isa::name`]; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<Isa> {
        ALL.into_iter().find(|isa| isa.name() == s)
    }

    /// Decode a persisted discriminant (tuner table codec).
    pub fn from_u8(v: u8) -> Option<Isa> {
        ALL.into_iter().find(|isa| *isa as u8 == v)
    }

    /// Can this variant run on the current host?
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every variant available on this host, ascending (scalar first).
    pub fn detected() -> Vec<Isa> {
        ALL.into_iter().filter(|isa| isa.available()).collect()
    }

    /// The widest available variant (what `Forced` mode defaults to and
    /// what an untuned table routes everything to).
    pub fn best() -> Isa {
        *Isa::detected().last().expect("scalar is always available")
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Generates the six panel routines in terms of the enclosing module's
/// `axpy`/`vadd` helpers. `$attr` is forwarded to every generated fn so
/// feature-gated modules (AVX2) put `#[target_feature]` on the whole
/// panel — dispatch pays the feature check once per panel, not per row.
/// All generated fns are uniformly `unsafe` (the intrinsic modules need
/// it; the scalar module just inherits the signature).
macro_rules! define_panel_kernels {
    ($(#[$attr:meta])*) => {
        /// One M-panel of `sgemm`: rows `row0..row0+rows` of `C`, row
        /// init from `bias` (`None` = zero), exact-zero A entries
        /// skipped, k ascending per row.
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host (see
        /// [`Isa::available`](super::Isa::available)); all memory access
        /// is bounds-checked slice indexing.
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        pub(super) unsafe fn sgemm_panel(
            c_panel: &mut [f32],
            row0: usize,
            n: usize,
            k: usize,
            a: &[f32],
            b: &[f32],
            bias: Option<&[f32]>,
        ) {
            for (r, crow) in c_panel.chunks_exact_mut(n).enumerate() {
                match bias {
                    Some(init) => crow.copy_from_slice(init),
                    None => crow.fill(0.0),
                }
                let arow = &a[(row0 + r) * k..][..k];
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    axpy(crow, &b[p * n..][..n], av);
                }
            }
        }

        /// One K-panel of `sgemm_atb`: rows `k0..k0+krows` of
        /// `dW += A^T D`, m ascending per row (the accumulation axis).
        /// Accumulates — callers zero `dw` (the `sgemm_atb` contract).
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host; all
        /// memory access is bounds-checked slice indexing.
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        pub(super) unsafe fn sgemm_atb_panel(
            dw_panel: &mut [f32],
            k0: usize,
            m: usize,
            n: usize,
            k: usize,
            a: &[f32],
            d: &[f32],
        ) {
            let krows = dw_panel.len() / n;
            for mi in 0..m {
                let arow = &a[mi * k + k0..][..krows];
                let drow = &d[mi * n..][..n];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    axpy(&mut dw_panel[kk * n..][..n], drow, av);
                }
            }
        }

        /// Direct 3x3 same-pad conv forward over a block of `nn`
        /// images — the `ops::reference::conv2d` nest verbatim, with the
        /// innermost per-`cout` loop as `axpy`. Deliberately NO
        /// exact-zero skip: the reference has none, and skipping would
        /// change signed-zero outputs.
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host; all
        /// memory access is bounds-checked slice indexing.
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        pub(super) unsafe fn conv_fwd_block(
            x: &[f32],
            nn: usize,
            h: usize,
            w: usize,
            cin: usize,
            wgt: &[f32],
            cout: usize,
            bias: &[f32],
            out: &mut [f32],
        ) {
            for orow in out.chunks_exact_mut(cout) {
                orow.copy_from_slice(bias);
            }
            for ni in 0..nn {
                for di in 0..3usize {
                    let (i0, i1) = super::tap_range(di, h);
                    for dj in 0..3usize {
                        let (j0, j1) = super::tap_range(dj, w);
                        for i in i0..i1 {
                            let xi = i + di - 1;
                            for j in j0..j1 {
                                let xj = j + dj - 1;
                                let xrow = &x[((ni * h + xi) * w + xj) * cin..][..cin];
                                let orow =
                                    &mut out[((ni * h + i) * w + j) * cout..][..cout];
                                for (ci, &xv) in xrow.iter().enumerate() {
                                    let wrow =
                                        &wgt[((di * 3 + dj) * cin + ci) * cout..][..cout];
                                    axpy(orow, wrow, xv);
                                }
                            }
                        }
                    }
                }
            }
        }

        /// One (di, dj) tap of the conv weight gradient: accumulates
        /// `dw_tap[ci*cout..]` over images/pixels in reference order,
        /// with the reference's exact-zero skip on `xv` (post-ReLU
        /// activations are ~half zeros).
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host; all
        /// memory access is bounds-checked slice indexing.
        $(#[$attr])*
        #[allow(clippy::too_many_arguments)]
        pub(super) unsafe fn conv_bwd_w_tap(
            x: &[f32],
            n: usize,
            h: usize,
            w: usize,
            cin: usize,
            dout: &[f32],
            cout: usize,
            dw_tap: &mut [f32],
            di: usize,
            dj: usize,
        ) {
            let (i0, i1) = super::tap_range(di, h);
            let (j0, j1) = super::tap_range(dj, w);
            for ni in 0..n {
                for i in i0..i1 {
                    let xi = i + di - 1;
                    for j in j0..j1 {
                        let xj = j + dj - 1;
                        let xrow = &x[((ni * h + xi) * w + xj) * cin..][..cin];
                        let drow = &dout[((ni * h + i) * w + j) * cout..][..cout];
                        for (ci, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            axpy(&mut dw_tap[ci * cout..][..cout], drow, xv);
                        }
                    }
                }
            }
        }

        /// col2im for one image: per destination pixel, zero then add
        /// the (up to 9) gathered tap columns in ascending (di, dj)
        /// order — `vadd` across the independent `cin` lanes.
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host; all
        /// memory access is bounds-checked slice indexing.
        $(#[$attr])*
        pub(super) unsafe fn col2im_image(
            g: &[f32],
            panel: &mut [f32],
            h: usize,
            w: usize,
            cin: usize,
            ni: usize,
        ) {
            let k = 9 * cin;
            for xi in 0..h {
                for xj in 0..w {
                    let drow = &mut panel[(xi * w + xj) * cin..][..cin];
                    drow.fill(0.0);
                    for di in 0..3usize {
                        if xi + 1 < di || xi + 1 - di >= h {
                            continue;
                        }
                        let i = xi + 1 - di;
                        for dj in 0..3usize {
                            if xj + 1 < dj || xj + 1 - dj >= w {
                                continue;
                            }
                            let j = xj + 1 - dj;
                            let grow = &g
                                [((ni * h + i) * w + j) * k + (di * 3 + dj) * cin..][..cin];
                            vadd(drow, grow);
                        }
                    }
                }
            }
        }

        /// Column sums of `dout` into `db` (bias gradient): rows
        /// ascending, `vadd` across the independent `cout` lanes.
        /// Does NOT zero `db` — callers accumulate into a zeroed slice.
        ///
        /// # Safety
        /// Caller must ensure this ISA is available on the host; all
        /// memory access is bounds-checked slice indexing.
        $(#[$attr])*
        pub(super) unsafe fn col_sum(db: &mut [f32], dout: &[f32], cout: usize) {
            for drow in dout.chunks_exact(cout) {
                vadd(db, drow);
            }
        }
    };
}

/// Portable plain-loop panels (the "scalar" variant). The `unsafe` on
/// `axpy`/`vadd` is signature-only (macro uniformity); the bodies are
/// safe code.
mod scalar {
    #[inline]
    unsafe fn axpy(acc: &mut [f32], src: &[f32], s: f32) {
        for (c, &v) in acc.iter_mut().zip(src) {
            *c += s * v;
        }
    }

    #[inline]
    unsafe fn vadd(acc: &mut [f32], src: &[f32]) {
        for (c, &v) in acc.iter_mut().zip(src) {
            *c += v;
        }
    }

    define_panel_kernels!();
}

#[cfg(target_arch = "x86_64")]
mod sse2 {
    use std::arch::x86_64::*;

    /// `acc[i] += s * src[i]`, 4 lanes at a time. Separate mul and add
    /// (never `_mm_fmadd_ps`): two roundings, exactly the scalar chain.
    #[inline]
    unsafe fn axpy(acc: &mut [f32], src: &[f32], s: f32) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let vs = _mm_set1_ps(s);
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm_mul_ps(vs, _mm_loadu_ps(sp.add(i)));
            _mm_storeu_ps(ap.add(i), _mm_add_ps(_mm_loadu_ps(ap.add(i)), prod));
            i += 4;
        }
        while i < n {
            *ap.add(i) += s * *sp.add(i);
            i += 1;
        }
    }

    #[inline]
    unsafe fn vadd(acc: &mut [f32], src: &[f32]) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            let sum = _mm_add_ps(_mm_loadu_ps(ap.add(i)), _mm_loadu_ps(sp.add(i)));
            _mm_storeu_ps(ap.add(i), sum);
            i += 4;
        }
        while i < n {
            *ap.add(i) += *sp.add(i);
            i += 1;
        }
    }

    define_panel_kernels!();
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// `acc[i] += s * src[i]`, 8 lanes at a time. Separate mul and add
    /// (never `_mm256_fmadd_ps`): two roundings, exactly the scalar
    /// chain, even though AVX2 hosts always have FMA.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn axpy(acc: &mut [f32], src: &[f32], s: f32) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let vs = _mm256_set1_ps(s);
        let mut i = 0;
        while i + 8 <= n {
            let prod = _mm256_mul_ps(vs, _mm256_loadu_ps(sp.add(i)));
            _mm256_storeu_ps(ap.add(i), _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), prod));
            i += 8;
        }
        while i < n {
            *ap.add(i) += s * *sp.add(i);
            i += 1;
        }
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn vadd(acc: &mut [f32], src: &[f32]) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let mut i = 0;
        while i + 8 <= n {
            let sum = _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(sp.add(i)));
            _mm256_storeu_ps(ap.add(i), sum);
            i += 8;
        }
        while i < n {
            *ap.add(i) += *sp.add(i);
            i += 1;
        }
    }

    define_panel_kernels!(#[target_feature(enable = "avx2")]);
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// `acc[i] += s * src[i]`, 4 lanes at a time. `vmulq` then `vaddq`
    /// (never `vfmaq_f32`): two roundings, exactly the scalar chain.
    #[inline]
    unsafe fn axpy(acc: &mut [f32], src: &[f32], s: f32) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let vs = vdupq_n_f32(s);
        let mut i = 0;
        while i + 4 <= n {
            let prod = vmulq_f32(vs, vld1q_f32(sp.add(i)));
            vst1q_f32(ap.add(i), vaddq_f32(vld1q_f32(ap.add(i)), prod));
            i += 4;
        }
        while i < n {
            *ap.add(i) += s * *sp.add(i);
            i += 1;
        }
    }

    #[inline]
    unsafe fn vadd(acc: &mut [f32], src: &[f32]) {
        let n = acc.len().min(src.len());
        let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(ap.add(i), vaddq_f32(vld1q_f32(ap.add(i)), vld1q_f32(sp.add(i))));
            i += 4;
        }
        while i < n {
            *ap.add(i) += *sp.add(i);
            i += 1;
        }
    }

    define_panel_kernels!();
}

/// Dispatch one panel call to the `isa`-selected module.
///
/// SAFETY: panel bodies only do bounds-checked slice access plus
/// baseline or feature-gated intrinsics. Non-baseline arms are only
/// reachable for ISAs that [`Isa::available`] reported (the forced-mode
/// parser and the tuner both filter on it, and dispatch debug-asserts
/// it); ISAs of a foreign architecture fall through to scalar, which is
/// sound because all variants are bit-identical by contract.
macro_rules! dispatch {
    ($isa:expr, $f:ident($($arg:expr),* $(,)?)) => {{
        debug_assert!($isa.available(), "dispatch on unavailable ISA {:?}", $isa);
        match $isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => unsafe { sse2::$f($($arg),*) },
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::$f($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::$f($($arg),*) },
            _ => unsafe { scalar::$f($($arg),*) },
        }
    }};
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn sgemm_panel(
    isa: Isa,
    c_panel: &mut [f32],
    row0: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
) {
    dispatch!(isa, sgemm_panel(c_panel, row0, n, k, a, b, bias))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn sgemm_atb_panel(
    isa: Isa,
    dw_panel: &mut [f32],
    k0: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    d: &[f32],
) {
    dispatch!(isa, sgemm_atb_panel(dw_panel, k0, m, n, k, a, d))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_fwd_block(
    isa: Isa,
    x: &[f32],
    nn: usize,
    h: usize,
    w: usize,
    cin: usize,
    wgt: &[f32],
    cout: usize,
    bias: &[f32],
    out: &mut [f32],
) {
    dispatch!(isa, conv_fwd_block(x, nn, h, w, cin, wgt, cout, bias, out))
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_bwd_w_tap(
    isa: Isa,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    dout: &[f32],
    cout: usize,
    dw_tap: &mut [f32],
    di: usize,
    dj: usize,
) {
    dispatch!(isa, conv_bwd_w_tap(x, n, h, w, cin, dout, cout, dw_tap, di, dj))
}

pub(crate) fn col2im_image(
    isa: Isa,
    g: &[f32],
    panel: &mut [f32],
    h: usize,
    w: usize,
    cin: usize,
    ni: usize,
) {
    dispatch!(isa, col2im_image(g, panel, h, w, cin, ni))
}

pub(crate) fn col_sum(isa: Isa, db: &mut [f32], dout: &[f32], cout: usize) {
    dispatch!(isa, col_sum(db, dout, cout))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg32;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 41);
        // mixed signs + exact zeros so every skip path runs
        (0..n)
            .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn detection_is_sane() {
        let det = Isa::detected();
        assert_eq!(det[0], Isa::Scalar, "scalar is always first");
        assert!(det.windows(2).all(|w| w[0] < w[1]), "ascending");
        assert_eq!(Isa::best(), *det.last().unwrap());
        for isa in det {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert_eq!(Isa::from_u8(isa as u8), Some(isa));
        }
        assert_eq!(Isa::parse("avx512"), None);
        assert_eq!(Isa::from_u8(9), None);
    }

    /// Panel-level pin at lengths that straddle every vector width
    /// (1..=19 covers 4- and 8-lane bodies plus every tail size). The
    /// op- and net-level matrices live in `tests/native_gemm.rs`.
    #[test]
    fn panels_are_bitwise_identical_across_detected_isas() {
        for isa in Isa::detected().into_iter().skip(1) {
            for n in 1..=19usize {
                let (m, k) = (3usize, 7);
                let a = randv(m * k, 100 + n as u64);
                let b = randv(k * n, 200 + n as u64);
                let bias = randv(n, 300 + n as u64);
                let mut want = vec![0.0f32; m * n];
                let mut got = vec![0.0f32; m * n];
                sgemm_panel(Isa::Scalar, &mut want, 0, n, k, &a, &b, Some(&bias));
                sgemm_panel(isa, &mut got, 0, n, k, &a, &b, Some(&bias));
                assert_eq!(bits(&want), bits(&got), "sgemm_panel {isa} n={n}");

                let mut want_dw = vec![0.0f32; k * n];
                let mut got_dw = vec![0.0f32; k * n];
                sgemm_atb_panel(Isa::Scalar, &mut want_dw, 0, m, n, k, &a, &b);
                sgemm_atb_panel(isa, &mut got_dw, 0, m, n, k, &a, &b);
                assert_eq!(bits(&want_dw), bits(&got_dw), "sgemm_atb_panel {isa} n={n}");

                let rows = randv(6 * n, 400 + n as u64);
                let mut want_db = vec![0.0f32; n];
                let mut got_db = vec![0.0f32; n];
                col_sum(Isa::Scalar, &mut want_db, &rows, n);
                col_sum(isa, &mut got_db, &rows, n);
                assert_eq!(bits(&want_db), bits(&got_db), "col_sum {isa} n={n}");
            }
        }
    }
}
