//! PCG32 pseudo-random generator (O'Neill 2014) with distribution helpers.
//!
//! Every stochastic component in the framework — dataset synthesis, MPQ
//! config sampling, Rademacher probes, bootstrap resampling — derives from
//! this generator, so entire experiments replay bit-exactly from a seed.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent generator (e.g. per worker / per class).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed, stream)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for n << 2^32).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2 as f64).cos()) as f32;
            }
        }
    }

    /// Rademacher +-1.
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a vector with Rademacher draws (Hutchinson probes).
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rademacher()).collect()
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(42, 1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(42, 1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let c: Vec<u32> = {
            let mut r = Pcg32::new(42, 2);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg32::new(7, 0);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(9, 3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(1, 1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rademacher_is_pm1_and_balanced() {
        let mut r = Pcg32::new(3, 5);
        let v = r.rademacher_vec(10_000);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.05);
    }

    #[test]
    fn fork_decorrelates() {
        let mut base = Pcg32::new(11, 0);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(1);
        let a: Vec<u32> = (0..4).map(|_| f1.next_u32()).collect();
        let b: Vec<u32> = (0..4).map(|_| f2.next_u32()).collect();
        assert_ne!(a, b);
    }
}
