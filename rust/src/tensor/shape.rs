//! Minimal shape type for manifest-described tensors.

/// Row-major tensor shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Dims as i64 for xla::Literal::reshape.
    pub fn dims_i64(&self) -> Vec<i64> {
        self.0.iter().map(|&d| d as i64).collect()
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_display() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.rank(), 3);
        assert_eq!(format!("{s}"), "[2, 3, 4]");
        assert_eq!(Shape::new(&[]).numel(), 1); // scalar
    }
}
