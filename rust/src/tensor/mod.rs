//! Flat-buffer tensor utilities and deterministic RNG.
//!
//! The runtime owns all model state as flat `f32` vectors (DESIGN.md key
//! decision #2); this module provides the shape bookkeeping and per-block
//! views used to address them, plus the PCG-based RNG every synthetic
//! workload in the framework derives from (no `rand` dependency — the
//! substrate is built from scratch and seeded for bit-exact reruns).

mod rng;
mod shape;

pub use rng::Pcg32;
pub use shape::Shape;

/// A (offset, size) window into a flat parameter vector — one quantizable
/// block, as recorded in the artifact manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockView {
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

impl BlockView {
    pub fn slice<'a>(&self, flat: &'a [f32]) -> &'a [f32] {
        &flat[self.offset..self.offset + self.size]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32]) -> &'a mut [f32] {
        &mut flat[self.offset..self.offset + self.size]
    }
}

/// Min and max of a slice (None for empty input).
pub fn min_max(xs: &[f32]) -> Option<(f32, f32)> {
    if xs.is_empty() {
        return None;
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    Some((lo, hi))
}

/// Squared l2 norm.
pub fn sqnorm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_view_slices() {
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let b = BlockView { name: "w".into(), offset: 3, size: 4 };
        assert_eq!(b.slice(&flat), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn min_max_basics() {
        assert_eq!(min_max(&[]), None);
        assert_eq!(min_max(&[2.0]), Some((2.0, 2.0)));
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), Some((-1.0, 3.0)));
    }

    #[test]
    fn sqnorm_matches_manual() {
        assert_eq!(sqnorm(&[3.0, 4.0]), 25.0);
        assert_eq!(sqnorm(&[]), 0.0);
    }
}
