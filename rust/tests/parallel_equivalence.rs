//! Serial-vs-parallel equivalence: the determinism contract of
//! `coordinator::parallel` (results at `jobs = N` are bit-identical to
//! `jobs = 1`), exercised on the pure pool and on a small end-to-end
//! `run_study` — over PJRT artifacts when present, else the zero-setup
//! native backend, so the study-level check runs on every checkout.

use fitq::coordinator::{derive_seed, run_pool, run_study, Pipeline, StudyOptions};

mod common;
use common::runtime;

/// Equal, treating two NaNs as equal (rank correlations can be NaN when a
/// metric is constant across the sampled configs).
fn same(a: f64, b: f64) -> bool {
    (a.is_nan() && b.is_nan()) || a == b
}

#[test]
fn pool_is_bit_identical_across_job_counts() {
    // deterministic-but-chunky work: a per-index seeded integer mix
    let work = |_w: &mut (), i: usize| -> anyhow::Result<u64> {
        let mut x = derive_seed(42, i as u64);
        for _ in 0..10_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x ^= x >> 29;
        }
        Ok(x)
    };
    let serial = run_pool(64, 1, || Ok(()), work).unwrap();
    for jobs in [2usize, 4, 7, 0] {
        let par = run_pool(64, jobs, || Ok(()), work).unwrap();
        assert_eq!(serial, par, "jobs={jobs} must match the serial reference");
    }
}

#[test]
fn pool_init_runs_once_per_worker_without_reordering() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let inits = AtomicUsize::new(0);
    let out = run_pool(
        40,
        4,
        || {
            inits.fetch_add(1, Ordering::Relaxed);
            Ok(())
        },
        |_, i| Ok(2 * i),
    )
    .unwrap();
    assert_eq!(out, (0..40).map(|i| 2 * i).collect::<Vec<_>>());
    assert!(inits.load(Ordering::Relaxed) <= 4, "at most one init per worker");
}

#[test]
fn run_study_identical_at_jobs_1_and_4() {
    // end-to-end equivalence; runs everywhere now that the native backend
    // exists (PJRT is used when artifacts are present)
    let rt = runtime();
    let mut opt = StudyOptions {
        n_configs: 6,
        fp_epochs: 3,
        qat_epochs: 1,
        eval_n: 128,
        seed: 11,
        ..Default::default()
    };
    opt.trace.max_iters = 40;

    // distinct cold pipelines per run: the study cache is jobs-agnostic by
    // design, so sharing one would turn the second run into a cache read
    // instead of an actual parallel sweep
    let dir = |tag: &str| {
        let d = std::env::temp_dir().join(format!("fitq_pareq_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    };
    let (d1, d4) = (dir("j1"), dir("j4"));

    opt.jobs = 1;
    let pipe1 = Pipeline::new(&d1).expect("pipeline");
    let serial = run_study(&rt, &pipe1, "cnn_mnist", &opt).expect("serial study");
    opt.jobs = 4;
    let pipe4 = Pipeline::new(&d4).expect("pipeline");
    let par = run_study(&rt, &pipe4, "cnn_mnist", &opt).expect("parallel study");
    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d4).ok();

    assert_eq!(serial.outcomes.len(), par.outcomes.len());
    for (a, b) in serial.outcomes.iter().zip(&par.outcomes) {
        assert_eq!(a.cfg, b.cfg, "config sampling must not depend on jobs");
        assert!(same(a.test_score, b.test_score), "{} vs {}", a.test_score, b.test_score);
        assert!(same(a.train_score, b.train_score), "{} vs {}", a.train_score, b.train_score);
        for ((m1, v1), (m2, v2)) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(m1, m2);
            match (v1, v2) {
                (Some(x), Some(y)) => assert!(same(*x, *y), "{m1:?}: {x} vs {y}"),
                (None, None) => {}
                other => panic!("{m1:?}: mismatched applicability {other:?}"),
            }
        }
    }
    // identical Spearman outputs — the acceptance check for the sweep
    for ((m1, r1), (m2, r2)) in serial.correlations.iter().zip(&par.correlations) {
        assert_eq!(m1, m2);
        match (r1, r2) {
            (Some(x), Some(y)) => assert!(same(*x, *y), "{m1:?}: rho {x} vs {y}"),
            (None, None) => {}
            other => panic!("{m1:?}: mismatched correlation {other:?}"),
        }
    }
}
