//! Native-backend verification: finite-difference gradient checks for
//! every backward kernel, the straight-through-estimator identity,
//! bit-exact determinism across runs and `--jobs` values, and a small
//! end-to-end train → EF-trace loop through the `Runtime` dispatch path.
//!
//! The conv/dense gradchecks run against the scalar `ops::reference`
//! oracles — the ground truth the GEMM path is pinned to at 0 ULP by
//! `tests/native_gemm.rs`, so the checks transfer to the GEMM kernels
//! verbatim; whole-net checks exercise the GEMM path itself.
//!
//! Gradcheck scheme (tolerances calibrated against a NumPy mirror of
//! these kernels validated against the JAX reference graphs): scalar
//! objective `L = sum(c * kernel_out)` with fixed random `c` (analytic
//! gradient = backward with `dout = c`), central differences along a
//! random unit direction, and the *achieved* f32 perturbation
//! `theta+ - theta-` used on the analytic side so input rounding cancels.
//! Kernels are smooth (conv/dense/BN/CE), so `h = 1e-2` holds the
//! relative error at or below 1e-3 with an order-of-magnitude margin.

use fitq::coordinator::{
    dataset_for, run_pool, Estimator, ModelState, TraceEngine, TraceOptions, Trainer,
};
use fitq::data::{EpochBatch, SynthClass};
use fitq::native::model::{Plan, STUDY_CNNS};
use fitq::native::net::{self, QuantArgs};
use fitq::native::ops::{reference, ExecCtx};
use fitq::native::{ops, quant};
use fitq::runtime::{Arg, Runtime};
use fitq::tensor::Pcg32;

const H: f32 = 1e-2;
const TOL: f64 = 1e-3;

fn randv(n: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 11);
    (0..n).map(|_| rng.normal() * scale).collect()
}

fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Central-difference directional check of `grad` against `f` at `theta`.
fn fd_check(name: &str, theta: &[f32], grad: &[f32], f: impl Fn(&[f32]) -> f64, h: f32, tol: f64) {
    let mut rng = Pcg32::new(0x0d17ec7, 7);
    let mut d: Vec<f32> = (0..theta.len()).map(|_| rng.normal()).collect();
    let norm = d.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt() as f32;
    for v in &mut d {
        *v /= norm;
    }
    let tp: Vec<f32> = theta.iter().zip(&d).map(|(&t, &dv)| t + h * dv).collect();
    let tm: Vec<f32> = theta.iter().zip(&d).map(|(&t, &dv)| t - h * dv).collect();
    let fd = f(&tp) - f(&tm);
    let an: f64 = grad
        .iter()
        .zip(tp.iter().zip(&tm))
        .map(|(&g, (&p, &m))| g as f64 * (p as f64 - m as f64))
        .sum();
    let rel = (fd - an).abs() / an.abs().max(1e-12);
    assert!(rel <= tol, "{name}: FD rel err {rel:.3e} > {tol:.0e} (fd {fd:.6e}, an {an:.6e})");
}

#[test]
fn gradcheck_conv2d() {
    let (n, h, w, cin, cout) = (2usize, 6, 6, 3, 4);
    let x = randv(n * h * w * cin, 1.0, 1);
    let wgt = randv(9 * cin * cout, 0.3, 2);
    let bias = randv(cout, 0.1, 3);
    let c = randv(n * h * w * cout, 1.0, 4);

    let mut dw = vec![0.0f32; wgt.len()];
    let mut db = vec![0.0f32; cout];
    reference::conv2d_bwd_w(&x, n, h, w, cin, &c, cout, &mut dw, &mut db);
    let mut dx = vec![0.0f32; x.len()];
    reference::conv2d_bwd_x(&wgt, n, h, w, cin, &c, cout, &mut dx);

    let run = |xx: &[f32], ww: &[f32], bb: &[f32]| {
        let mut out = vec![0.0f32; n * h * w * cout];
        reference::conv2d(xx, n, h, w, cin, ww, cout, bb, &mut out);
        dot64(&c, &out)
    };
    fd_check("conv2d d/dw", &wgt, &dw, |t| run(&x, t, &bias), H, TOL);
    fd_check("conv2d d/dx", &x, &dx, |t| run(t, &wgt, &bias), H, TOL);
    fd_check("conv2d d/db", &bias, &db, |t| run(&x, &wgt, t), H, TOL);
}

#[test]
fn gradcheck_dense() {
    let (n, fin, fout) = (4usize, 24, 10);
    let x = randv(n * fin, 1.0, 5);
    let wgt = randv(fin * fout, 0.3, 6);
    let bias = randv(fout, 0.1, 7);
    let c = randv(n * fout, 1.0, 8);

    let mut dw = vec![0.0f32; wgt.len()];
    let mut db = vec![0.0f32; fout];
    let mut dx = vec![0.0f32; x.len()];
    reference::dense_bwd(&x, &wgt, n, fin, fout, &c, &mut dw, &mut db, &mut dx);

    let run = |xx: &[f32], ww: &[f32], bb: &[f32]| {
        let mut out = vec![0.0f32; n * fout];
        reference::dense(xx, n, fin, ww, fout, bb, &mut out);
        dot64(&c, &out)
    };
    fd_check("dense d/dw", &wgt, &dw, |t| run(&x, t, &bias), H, TOL);
    fd_check("dense d/dx", &x, &dx, |t| run(t, &wgt, &bias), H, TOL);
    fd_check("dense d/db", &bias, &db, |t| run(&x, &wgt, t), H, TOL);
}

#[test]
fn gradcheck_batch_norm() {
    let (m, c) = (96usize, 5);
    let x = randv(m * c, 1.0, 9);
    let gamma: Vec<f32> = randv(c, 0.2, 10).iter().map(|v| 1.0 + v).collect();
    let beta = randv(c, 0.1, 11);
    let cw = randv(m * c, 1.0, 12);

    let fwd = |xx: &[f32], g: &[f32], b: &[f32]| {
        let mut out = vec![0.0f32; m * c];
        let mut xhat = vec![0.0f32; m * c];
        let mut ivar = vec![0.0f32; c];
        ops::batch_norm(xx, m, c, g, b, &mut out, &mut xhat, &mut ivar);
        (out, xhat, ivar)
    };
    let (_, xhat, ivar) = fwd(&x, &gamma, &beta);
    let mut dx = vec![0.0f32; m * c];
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    ops::batch_norm_bwd(&cw, &xhat, &ivar, &gamma, m, c, &mut dx, &mut dgamma, &mut dbeta);

    let f = |xx: &[f32], g: &[f32], b: &[f32]| dot64(&cw, &fwd(xx, g, b).0);
    fd_check("batch_norm d/dx", &x, &dx, |t| f(t, &gamma, &beta), H, TOL);
    fd_check("batch_norm d/dgamma", &gamma, &dgamma, |t| f(&x, t, &beta), H, TOL);
    fd_check("batch_norm d/dbeta", &beta, &dbeta, |t| f(&x, &gamma, t), H, TOL);
}

#[test]
fn gradcheck_softmax_ce() {
    let (n, ncls) = (8usize, 10);
    let logits = randv(n * ncls, 1.0, 13);
    let labels: Vec<i32> = {
        let mut rng = Pcg32::new(14, 3);
        (0..n).map(|_| rng.below(ncls as u32) as i32).collect()
    };
    let mut dl = vec![0.0f32; n * ncls];
    let dper = vec![1.0f32 / n as f32; n];
    ops::softmax_xent_bwd(&logits, &labels, n, ncls, &dper, &mut dl);
    let f = |t: &[f32]| {
        let mut per = vec![0.0f32; n];
        ops::softmax_xent(t, &labels, n, ncls, &mut per);
        per.iter().map(|&v| v as f64).sum::<f64>() / n as f64
    };
    fd_check("softmax_ce d/dlogits", &logits, &dl, f, H, TOL);
}

#[test]
fn gradcheck_max_pool() {
    // window values spaced >= 0.05 apart so the h=1e-2 probe can never
    // swap a winner (max-pool is only piecewise linear)
    let (n, h, w, c) = (1usize, 6, 6, 2);
    let len = n * h * w * c;
    let mut x: Vec<f32> = (0..len).map(|k| k as f32 * 0.05).collect();
    let mut rng = Pcg32::new(15, 1);
    for i in (1..len).rev() {
        x.swap(i, rng.below(i as u32 + 1) as usize);
    }
    let cw = randv(len / 4, 1.0, 16);
    let run = |xx: &[f32]| {
        let mut out = vec![0.0f32; len / 4];
        let mut idx = vec![0u8; len / 4];
        ops::max_pool(xx, n, h, w, c, &mut out, &mut idx);
        (out, idx)
    };
    let (_, idx) = run(&x);
    let mut dx = vec![0.0f32; len];
    ops::max_pool_bwd(&cw, &idx, n, h, w, c, &mut dx);
    fd_check("max_pool d/dx", &x, &dx, |t| dot64(&cw, &run(t).0), H, TOL);
}

/// Whole-net directional checks. ReLU kinks and BN conditioning make the
/// composed loss only piecewise smooth, so these carry looser, documented
/// tolerances (the per-kernel checks above hold the 1e-3 bar).
#[test]
fn gradcheck_whole_net() {
    for (spec, tol) in [(STUDY_CNNS[0], 1e-2), (STUDY_CNNS[1], 1e-1)] {
        let plan = Plan::new(spec);
        let params = plan.init_flat(3);
        let x = randv(8 * plan.sample_len(), 1.0, 17);
        let y: Vec<i32> = {
            let mut rng = Pcg32::new(18, 2);
            (0..8).map(|_| rng.below(10) as i32).collect()
        };
        let (_, grads) =
            net::mean_loss_grad(&plan, &params, &x, &y, 8, None, &mut ExecCtx::serial());
        fd_check(
            &format!("{} mean loss d/dparams", spec.name),
            &params,
            &grads.flat,
            |t| net::mean_loss_grad(&plan, t, &x, &y, 8, None, &mut ExecCtx::serial()).0 as f64,
            3e-3,
            tol,
        );
    }
}

#[test]
fn ste_backward_is_identity_through_quant_nodes() {
    // bits = 0 makes fake_quant degenerate to the identity function, so
    // the QAT forward AND backward must match the FP path bit-for-bit —
    // pinning that the backward *skips* quantization nodes (the STE)
    // rather than differentiating through them.
    let plan = Plan::new(STUDY_CNNS[0]);
    let params = plan.init_flat(5);
    let x = randv(4 * plan.sample_len(), 1.0, 19);
    let y = vec![1i32, 3, 5, 7];
    let mut ctx = ExecCtx::serial();
    let (l_fp, g_fp) = net::mean_loss_grad(&plan, &params, &x, &y, 4, None, &mut ctx);
    let (lw, la) = (plan.n_weight_blocks(), plan.n_act_blocks());
    let (bits_w, bits_a) = (vec![0.0f32; lw], vec![0.0f32; la]);
    let (lo, hi) = (vec![0.0f32; la], vec![1.0f32; la]);
    let q = QuantArgs { bits_w: &bits_w, bits_a: &bits_a, act_lo: &lo, act_hi: &hi };
    let (l_q, g_q) = net::mean_loss_grad(&plan, &params, &x, &y, 4, Some(q), &mut ctx);
    assert_eq!(l_fp.to_bits(), l_q.to_bits());
    assert_eq!(
        g_fp.flat.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        g_q.flat.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );

    // active quantization: the quantized forward is piecewise constant
    // (no gradient of its own), yet STE gradients land on the raw weight
    // slots, finite and nonzero
    let (bits_w4, bits_a4) = (vec![4.0f32; lw], vec![4.0f32; la]);
    let (lo4, hi4) = (vec![0.0f32; la], vec![4.0f32; la]);
    let q4 = QuantArgs { bits_w: &bits_w4, bits_a: &bits_a4, act_lo: &lo4, act_hi: &hi4 };
    let (l4, g4) = net::mean_loss_grad(&plan, &params, &x, &y, 4, Some(q4), &mut ctx);
    assert!(l4.is_finite());
    for l in 0..lw {
        let (off, size) = plan.weight_block(l);
        assert!(
            g4.flat[off..off + size].iter().any(|&g| g != 0.0 && g.is_finite()),
            "block {l} must receive STE gradients"
        );
    }

    // and fake_quant itself is locally constant away from boundaries
    let xs = randv(64, 1.0, 20);
    let mut q1 = vec![0.0f32; 64];
    let mut q2 = vec![0.0f32; 64];
    quant::fake_quant(&xs, -3.0, 3.0, 4.0, &mut q1);
    let nudged: Vec<f32> = xs.iter().map(|&v| v + 1e-5).collect();
    quant::fake_quant(&nudged, -3.0, 3.0, 4.0, &mut q2);
    let same = q1.iter().zip(&q2).filter(|(a, b)| a == b).count();
    assert!(same >= 60, "fake_quant must be piecewise constant ({same}/64 unchanged)");
}

fn train_epoch_bits(rt: &Runtime, seed: u32) -> Vec<u32> {
    let mm = rt.model("cnn_mnist").unwrap().clone();
    let exe = rt.load("cnn_mnist", "train_epoch").unwrap();
    let st = ModelState::init(rt, "cnn_mnist", seed).unwrap();
    let ds = SynthClass::synmnist(seed as u64);
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    let out = exe
        .run(&[
            Arg::F32(&st.params),
            Arg::F32(&st.m),
            Arg::F32(&st.v),
            Arg::F32Scalar(0.0),
            Arg::F32(&eb.xs),
            Arg::I32(&eb.ys),
        ])
        .unwrap();
    let mut bits: Vec<u32> =
        out.f32("params").unwrap().iter().map(|v| v.to_bits()).collect();
    bits.push(out.scalar("loss").unwrap().to_bits());
    bits
}

#[test]
fn train_epoch_bit_identical_across_runs_and_jobs() {
    // same seed, fresh runtimes: bit-identical params and loss
    let a = train_epoch_bits(&Runtime::native().unwrap(), 3);
    let b = train_epoch_bits(&Runtime::native().unwrap(), 3);
    assert_eq!(a, b, "two runs must replay bit-exactly");
    assert_ne!(a, train_epoch_bits(&Runtime::native().unwrap(), 4), "seed must matter");

    // the intra-op GEMM thread budget is a pure wall-clock knob: a
    // 4-thread runtime must replay the serial bits exactly
    let c = train_epoch_bits(&Runtime::native_with_threads(4).unwrap(), 3);
    assert_eq!(a, c, "intra-op threading must not change a single bit");

    // and across --jobs values: a pool of per-seed epochs is bitwise
    // invariant to the worker count (the parallel determinism contract)
    let epochs = |jobs: usize| -> Vec<Vec<u32>> {
        run_pool(6, jobs, Runtime::native, |rt, i| Ok(train_epoch_bits(rt, i as u32))).unwrap()
    };
    assert_eq!(epochs(1), epochs(4));
}

#[test]
fn native_runtime_end_to_end_train_and_trace() {
    // the zero-setup loop: init -> FP epochs -> EF trace, all through the
    // Runtime dispatch path (no artifacts directory anywhere near this)
    let rt = Runtime::native().unwrap();
    let ds = dataset_for(&rt, "cnn_mnist", 1).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, "cnn_mnist", 1).unwrap();
    let losses = trainer.train(&mut st, 3).unwrap();
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "3 FP epochs must reduce the loss: {losses:?}"
    );
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let opt = TraceOptions::fixed_iters(32, 5, 1);
    let r = engine.run("cnn_mnist", &st.params, Estimator::EmpiricalFisher, opt).unwrap();
    assert_eq!(r.w_traces.len(), 4);
    assert_eq!(r.a_traces.len(), 3);
    assert!(r.w_traces.iter().all(|&t| t.is_finite() && t > 0.0));
    assert_eq!(r.iterations, 5);
}

#[test]
fn native_entry_validation_matches_manifest() {
    let rt = Runtime::native().unwrap();
    let exe = rt.load("cnn_mnist", "init").unwrap();
    assert!(exe.run(&[Arg::F32Scalar(1.0)]).is_err(), "dtype mismatch");
    assert!(exe.run(&[]).is_err(), "arity mismatch");
    let pr = rt.load("cnn_mnist", "param_ranges").unwrap();
    let too_short = vec![0.0f32; 3];
    assert!(pr.run(&[Arg::F32(&too_short)]).is_err(), "shape mismatch");
    // entries absent from the study set stay absent
    assert!(rt.load("cnn_mnist", "hutch_bs4").is_err());
    assert!(rt.load("cnn_s", "init").is_err(), "scale models are PJRT-only");
}
