//! GEMM-vs-reference equivalence: the native backend's im2col + GEMM
//! kernels (`native::gemm`, the `ops` wrappers) must reproduce the
//! scalar `ops::reference` loop nests to 0 ULP — same bits, every
//! shape, every thread budget. This is the contract that lets the GEMM
//! layer replace the loop nests without bumping a single pipeline cache
//! digest (DESIGN.md "Native math kernels").

use std::sync::Arc;

use fitq::native::gemm::{self, ExecCtx};
use fitq::native::model::{Plan, STUDY_CNNS};
use fitq::native::net::{self, QuantArgs};
use fitq::native::ops::{self, reference};
use fitq::native::simd::Isa;
use fitq::native::tune;
use fitq::tensor::Pcg32;

fn randv(n: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 77);
    (0..n).map(|_| rng.normal() * scale).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Odd conv geometries: nothing a multiple of the MR/NR/KC tile sizes,
/// single-sample batches, single channels, non-square images.
const CONV_SHAPES: &[(usize, usize, usize, usize, usize)] = &[
    (1, 2, 2, 1, 1),
    (1, 5, 7, 3, 5),
    (2, 4, 4, 1, 8),
    (3, 6, 5, 2, 10),
    (1, 3, 9, 4, 3),
    (2, 16, 16, 8, 16), // a real study-model layer shape
];

#[test]
fn conv2d_forward_matches_reference_bitwise() {
    for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
        let x = randv(n * h * w * cin, 1.0, 100 + t as u64);
        let wgt = randv(9 * cin * cout, 0.4, 200 + t as u64);
        let bias = randv(cout, 0.1, 300 + t as u64);
        let mut want = vec![0.0f32; n * h * w * cout];
        reference::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut want);
        for threads in [1usize, 4] {
            let mut ctx = ExecCtx::new(threads);
            let mut got = vec![0.0f32; want.len()];
            ops::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut got, &mut ctx);
            assert_eq!(bits(&got), bits(&want), "shape {t} threads {threads}");
        }
    }
}

#[test]
fn conv2d_bwd_w_matches_reference_bitwise() {
    for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
        // post-ReLU-like input: exact zeros exercise the zero-skip path
        let mut x = randv(n * h * w * cin, 1.0, 400 + t as u64);
        for v in x.iter_mut() {
            *v = v.max(0.0);
        }
        let dout = randv(n * h * w * cout, 1.0, 500 + t as u64);
        let mut want_dw = vec![0.0f32; 9 * cin * cout];
        let mut want_db = vec![0.0f32; cout];
        reference::conv2d_bwd_w(&x, n, h, w, cin, &dout, cout, &mut want_dw, &mut want_db);
        for threads in [1usize, 4] {
            let mut ctx = ExecCtx::new(threads);
            let mut dw = vec![0.0f32; want_dw.len()];
            let mut db = vec![0.0f32; cout];
            ops::conv2d_bwd_w(&x, n, h, w, cin, &dout, cout, &mut dw, &mut db, &mut ctx);
            assert_eq!(bits(&dw), bits(&want_dw), "dw shape {t} threads {threads}");
            assert_eq!(bits(&db), bits(&want_db), "db shape {t} threads {threads}");
        }
    }
}

#[test]
fn conv2d_im2col_lowerings_match_reference_bitwise() {
    // the alternative im2col + GEMM lowerings (not routed by default —
    // see the measured routing rule in `native::gemm`) carry the same
    // 0-ULP contract as the production direct kernels
    for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
        let mut x = randv(n * h * w * cin, 1.0, 1200 + t as u64);
        for v in x.iter_mut().skip(1).step_by(2) {
            *v = v.max(0.0); // exact zeros through the skip paths
        }
        let wgt = randv(9 * cin * cout, 0.4, 1300 + t as u64);
        let bias = randv(cout, 0.1, 1400 + t as u64);
        let dout = randv(n * h * w * cout, 1.0, 1500 + t as u64);
        let mut want = vec![0.0f32; n * h * w * cout];
        reference::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut want);
        let mut want_dw = vec![0.0f32; 9 * cin * cout];
        let mut want_db = vec![0.0f32; cout];
        reference::conv2d_bwd_w(&x, n, h, w, cin, &dout, cout, &mut want_dw, &mut want_db);
        for threads in [1usize, 4] {
            let mut ctx = ExecCtx::new(threads);
            let mut got = vec![0.0f32; want.len()];
            ops::conv2d_im2col(&x, n, h, w, cin, &wgt, cout, &bias, &mut got, &mut ctx);
            assert_eq!(bits(&got), bits(&want), "fwd shape {t} threads {threads}");
            let mut dw = vec![0.0f32; want_dw.len()];
            let mut db = vec![0.0f32; cout];
            ops::conv2d_bwd_w_im2col(&x, n, h, w, cin, &dout, cout, &mut dw, &mut db, &mut ctx);
            assert_eq!(bits(&dw), bits(&want_dw), "dw shape {t} threads {threads}");
            assert_eq!(bits(&db), bits(&want_db), "db shape {t} threads {threads}");
        }
    }
}

#[test]
fn conv2d_bwd_x_matches_reference_bitwise() {
    for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
        let wgt = randv(9 * cin * cout, 0.4, 600 + t as u64);
        let dout = randv(n * h * w * cout, 1.0, 700 + t as u64);
        let mut want = vec![0.0f32; n * h * w * cin];
        reference::conv2d_bwd_x(&wgt, n, h, w, cin, &dout, cout, &mut want);
        for threads in [1usize, 4] {
            let mut ctx = ExecCtx::new(threads);
            let mut dx = vec![0.0f32; want.len()];
            ops::conv2d_bwd_x(&wgt, n, h, w, cin, &dout, cout, &mut dx, &mut ctx);
            assert_eq!(bits(&dx), bits(&want), "shape {t} threads {threads}");
        }
    }
}

#[test]
fn dense_fwd_bwd_match_reference_bitwise() {
    // odd (batch, fin, fout) incl. batch 1 and a real fc layer shape
    for (t, &(n, fin, fout)) in [(1usize, 3usize, 2usize), (5, 129, 10), (32, 256, 10)]
        .iter()
        .enumerate()
    {
        let x = randv(n * fin, 1.0, 800 + t as u64);
        let wgt = randv(fin * fout, 0.3, 900 + t as u64);
        let bias = randv(fout, 0.1, 1000 + t as u64);
        let dout = randv(n * fout, 1.0, 1100 + t as u64);

        let mut want = vec![0.0f32; n * fout];
        reference::dense(&x, n, fin, &wgt, fout, &bias, &mut want);
        let mut want_dw = vec![0.0f32; fin * fout];
        let mut want_db = vec![0.0f32; fout];
        let mut want_dx = vec![0.0f32; n * fin];
        reference::dense_bwd(
            &x, &wgt, n, fin, fout, &dout, &mut want_dw, &mut want_db, &mut want_dx,
        );

        for threads in [1usize, 4] {
            let mut ctx = ExecCtx::new(threads);
            let mut out = vec![0.0f32; want.len()];
            ops::dense(&x, n, fin, &wgt, fout, &bias, &mut out, &mut ctx);
            assert_eq!(bits(&out), bits(&want), "fwd shape {t} threads {threads}");
            let mut dw = vec![0.0f32; fin * fout];
            let mut db = vec![0.0f32; fout];
            let mut dx = vec![0.0f32; n * fin];
            ops::dense_bwd(&x, &wgt, n, fin, fout, &dout, &mut dw, &mut db, &mut dx, &mut ctx);
            assert_eq!(bits(&dw), bits(&want_dw), "dw shape {t} threads {threads}");
            assert_eq!(bits(&db), bits(&want_db), "db shape {t} threads {threads}");
            assert_eq!(bits(&dx), bits(&want_dx), "dx shape {t} threads {threads}");
        }
    }
}

#[test]
fn im2col_col2im_round_trip_is_tap_multiplicity() {
    // col2im(im2col(x)) multiplies each pixel by its valid-tap count
    // (9 interior / 6 edge / 4 corner); integer-valued x keeps the
    // repeated f32 sums exact. Exercised at a real study-layer geometry.
    let plan = Plan::new(STUDY_CNNS[2]); // cnn_cifar
    let layer = &plan.convs[1];
    let (n, h, w, cin) = (2usize, layer.h, layer.w, layer.c_in);
    let mut rng = Pcg32::new(9, 4);
    let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.below(21) as f32 - 10.0).collect();
    let mut a = Vec::new();
    gemm::im2col3x3(&x, n, h, w, cin, &mut a);
    assert_eq!(a.len(), layer.gemm_m(n) * layer.gemm_k(), "plan helpers agree with lowering");
    let mut back = vec![0.0f32; x.len()];
    gemm::col2im3x3(&a, n, h, w, cin, &mut back, 2, Isa::Scalar);
    for i in 0..h {
        let ri = if i == 0 || i == h - 1 { 2 } else { 3 };
        for j in 0..w {
            let rj = if j == 0 || j == w - 1 { 2 } else { 3 };
            for ni in 0..n {
                for ci in 0..cin {
                    let at = ((ni * h + i) * w + j) * cin + ci;
                    assert_eq!(back[at], x[at] * (ri * rj) as f32, "({ni},{i},{j},{ci})");
                }
            }
        }
    }
}

/// Whole-net A/B: a full forward + backward through every study model on
/// the GEMM path must be bit-identical to the reference path — in plain
/// FP mode and in QAT mode (quantized activations put exact grid values
/// and rich cancellation patterns through the kernels).
#[test]
fn whole_net_gemm_equals_reference_bitwise() {
    for spec in STUDY_CNNS {
        let plan = Plan::new(*spec);
        let params = plan.init_flat(13);
        let batch = 4;
        let x = randv(batch * plan.sample_len(), 1.0, 23);
        let y: Vec<i32> = {
            let mut rng = Pcg32::new(29, 6);
            (0..batch).map(|_| rng.below(plan.spec.n_classes as u32) as i32).collect()
        };
        let (lw, la) = (plan.n_weight_blocks(), plan.n_act_blocks());
        let (bits_w, bits_a) = (vec![4.0f32; lw], vec![4.0f32; la]);
        let (lo, hi) = (vec![0.0f32; la], vec![4.0f32; la]);
        for qat in [false, true] {
            let q = qat.then_some(QuantArgs {
                bits_w: &bits_w,
                bits_a: &bits_a,
                act_lo: &lo,
                act_hi: &hi,
            });
            let mut rctx = ExecCtx::serial();
            rctx.use_reference = true;
            let (l_ref, g_ref) = net::mean_loss_grad(&plan, &params, &x, &y, batch, q, &mut rctx);
            for threads in [1usize, 4] {
                let mut ctx = ExecCtx::new(threads);
                let (l, g) = net::mean_loss_grad(&plan, &params, &x, &y, batch, q, &mut ctx);
                assert_eq!(
                    l.to_bits(),
                    l_ref.to_bits(),
                    "{} qat={qat} threads={threads} loss",
                    spec.name
                );
                assert_eq!(
                    bits(&g.flat),
                    bits(&g_ref.flat),
                    "{} qat={qat} threads={threads} grads",
                    spec.name
                );
                for (i, (a, b)) in g.act.iter().zip(&g_ref.act).enumerate() {
                    assert_eq!(
                        bits(a),
                        bits(b),
                        "{} qat={qat} threads={threads} act grad {i}",
                        spec.name
                    );
                }
            }
        }
    }
}

/// The variant matrix: every detected SIMD ISA, forced through every
/// tunable op wrapper (both lowerings where two exist), at serial and
/// threaded budgets, must reproduce the scalar reference bit-for-bit.
/// This is the op-level half of the 0-ULP contract for the explicit
/// SIMD paths — whichever winner the autotuner picks on any host, it
/// was proven here first.
#[test]
fn forced_variant_matrix_is_bitwise_identical() {
    for isa in Isa::detected() {
        for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
            // exact zeros exercise the signed-zero-safe skip paths
            let mut x = randv(n * h * w * cin, 1.0, 3000 + t as u64);
            for v in x.iter_mut().skip(1).step_by(2) {
                *v = v.max(0.0);
            }
            let wgt = randv(9 * cin * cout, 0.4, 3100 + t as u64);
            let bias = randv(cout, 0.1, 3200 + t as u64);
            let dout = randv(n * h * w * cout, 1.0, 3300 + t as u64);
            let mut want = vec![0.0f32; n * h * w * cout];
            reference::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut want);
            let mut want_dw = vec![0.0f32; 9 * cin * cout];
            let mut want_db = vec![0.0f32; cout];
            reference::conv2d_bwd_w(&x, n, h, w, cin, &dout, cout, &mut want_dw, &mut want_db);
            let mut want_dx = vec![0.0f32; n * h * w * cin];
            reference::conv2d_bwd_x(&wgt, n, h, w, cin, &dout, cout, &mut want_dx);
            for threads in [1usize, 4] {
                let mut ctx = ExecCtx::forced(isa);
                ctx.threads = threads;
                let tag = format!("isa {isa} shape {t} threads {threads}");
                let mut got = vec![0.0f32; want.len()];
                ops::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut got, &mut ctx);
                assert_eq!(bits(&got), bits(&want), "fwd direct {tag}");
                got.fill(0.0);
                ops::conv2d_im2col(&x, n, h, w, cin, &wgt, cout, &bias, &mut got, &mut ctx);
                assert_eq!(bits(&got), bits(&want), "fwd im2col {tag}");
                let (mut dw, mut db) = (vec![0.0f32; want_dw.len()], vec![0.0f32; cout]);
                ops::conv2d_bwd_w(&x, n, h, w, cin, &dout, cout, &mut dw, &mut db, &mut ctx);
                assert_eq!(bits(&dw), bits(&want_dw), "dw direct {tag}");
                assert_eq!(bits(&db), bits(&want_db), "db direct {tag}");
                dw.fill(0.0);
                db.fill(0.0);
                ops::conv2d_bwd_w_im2col(&x, n, h, w, cin, &dout, cout, &mut dw, &mut db, &mut ctx);
                assert_eq!(bits(&dw), bits(&want_dw), "dw im2col {tag}");
                assert_eq!(bits(&db), bits(&want_db), "db im2col {tag}");
                let mut dx = vec![0.0f32; want_dx.len()];
                ops::conv2d_bwd_x(&wgt, n, h, w, cin, &dout, cout, &mut dx, &mut ctx);
                assert_eq!(bits(&dx), bits(&want_dx), "dx {tag}");
            }
        }
        // dense fwd + bwd at odd and real-layer shapes
        for (t, &(n, fin, fout)) in [(1usize, 3usize, 2usize), (5, 129, 10), (32, 256, 10)]
            .iter()
            .enumerate()
        {
            let x = randv(n * fin, 1.0, 3400 + t as u64);
            let wgt = randv(fin * fout, 0.3, 3500 + t as u64);
            let bias = randv(fout, 0.1, 3600 + t as u64);
            let dout = randv(n * fout, 1.0, 3700 + t as u64);
            let mut want = vec![0.0f32; n * fout];
            reference::dense(&x, n, fin, &wgt, fout, &bias, &mut want);
            let mut want_dw = vec![0.0f32; fin * fout];
            let mut want_db = vec![0.0f32; fout];
            let mut want_dx = vec![0.0f32; n * fin];
            reference::dense_bwd(
                &x, &wgt, n, fin, fout, &dout, &mut want_dw, &mut want_db, &mut want_dx,
            );
            for threads in [1usize, 4] {
                let mut ctx = ExecCtx::forced(isa);
                ctx.threads = threads;
                let tag = format!("isa {isa} dense {t} threads {threads}");
                let mut out = vec![0.0f32; want.len()];
                ops::dense(&x, n, fin, &wgt, fout, &bias, &mut out, &mut ctx);
                assert_eq!(bits(&out), bits(&want), "fwd {tag}");
                let mut dw = vec![0.0f32; fin * fout];
                let mut db = vec![0.0f32; fout];
                let mut dx = vec![0.0f32; n * fin];
                ops::dense_bwd(&x, &wgt, n, fin, fout, &dout, &mut dw, &mut db, &mut dx, &mut ctx);
                assert_eq!(bits(&dw), bits(&want_dw), "dw {tag}");
                assert_eq!(bits(&db), bits(&want_db), "db {tag}");
                assert_eq!(bits(&dx), bits(&want_dx), "dx {tag}");
            }
        }
    }
}

/// Whole-net half of the variant contract: a full forward + backward
/// through every study model must produce identical bits under the
/// forced-scalar path, every forced detected ISA, and the autotuned
/// route table (whatever winners this host's tuner picked), at serial
/// and threaded budgets, in FP and QAT modes. `FITQ_NATIVE_KERNEL` can
/// therefore never change results — only wall clock.
#[test]
fn whole_net_forced_and_tuned_variants_are_bitwise_identical() {
    let tuned = Arc::new(tune::tune(1));
    for spec in STUDY_CNNS {
        let plan = Plan::new(*spec);
        let params = plan.init_flat(13);
        let batch = 4;
        let x = randv(batch * plan.sample_len(), 1.0, 37);
        let y: Vec<i32> = {
            let mut rng = Pcg32::new(41, 6);
            (0..batch).map(|_| rng.below(plan.spec.n_classes as u32) as i32).collect()
        };
        let (lw, la) = (plan.n_weight_blocks(), plan.n_act_blocks());
        let (bits_w, bits_a) = (vec![4.0f32; lw], vec![4.0f32; la]);
        let (lo, hi) = (vec![0.0f32; la], vec![4.0f32; la]);
        for qat in [false, true] {
            let q = qat.then_some(QuantArgs {
                bits_w: &bits_w,
                bits_a: &bits_a,
                act_lo: &lo,
                act_hi: &hi,
            });
            let mut sctx = ExecCtx::forced(Isa::Scalar);
            let (l0, g0) = net::mean_loss_grad(&plan, &params, &x, &y, batch, q, &mut sctx);
            for threads in [1usize, 4] {
                let mut ctxs: Vec<(String, ExecCtx)> = Isa::detected()
                    .into_iter()
                    .map(|isa| {
                        let mut c = ExecCtx::forced(isa);
                        c.threads = threads;
                        (format!("forced {isa}"), c)
                    })
                    .collect();
                ctxs.push(("auto".into(), ExecCtx::with_routes(threads, tuned.clone())));
                for (label, mut ctx) in ctxs {
                    let (l, g) = net::mean_loss_grad(&plan, &params, &x, &y, batch, q, &mut ctx);
                    let tag = format!("{} qat={qat} threads={threads} {label}", spec.name);
                    assert_eq!(l.to_bits(), l0.to_bits(), "{tag} loss");
                    assert_eq!(bits(&g.flat), bits(&g0.flat), "{tag} grads");
                }
            }
        }
    }
}

/// Scratch-arena reuse across heterogeneous op shapes must not leak
/// state: interleave every layer shape through one context and compare
/// against fresh-context results.
#[test]
fn scratch_reuse_across_shapes_is_stateless() {
    let mut shared = ExecCtx::serial();
    for round in 0..2 {
        for (t, &(n, h, w, cin, cout)) in CONV_SHAPES.iter().enumerate() {
            let x = randv(n * h * w * cin, 1.0, 2000 + t as u64);
            let wgt = randv(9 * cin * cout, 0.4, 2100 + t as u64);
            let bias = randv(cout, 0.1, 2200 + t as u64);
            let mut fresh = ExecCtx::serial();
            let mut a = vec![0.0f32; n * h * w * cout];
            let mut b = vec![0.0f32; n * h * w * cout];
            ops::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut a, &mut shared);
            ops::conv2d(&x, n, h, w, cin, &wgt, cout, &bias, &mut b, &mut fresh);
            assert_eq!(bits(&a), bits(&b), "round {round} shape {t}");
        }
    }
}
