//! Fuzz-lite: deterministic seeded byte-mutation loops over the
//! fail-closed parsers — the model-manifest parser
//! (`native::manifest`), the artifact-cache container header
//! (`pipeline::cache`), the binary payload codec (`pipeline::codec`),
//! the lease-record parser (`pipeline::cache::LeaseRecord`), and the
//! trace-report input path (`codec::decode_optrace` plus the
//! `coordinator::analysis` bench parser). No cargo-fuzz in this
//! container, so this is the bounded in-tree half of the ROADMAP
//! hardening item: a splitmix64 stream drives ~14k mutations per
//! `cargo test -q` run, and every mutated input must produce an error
//! or a valid value — never a panic, never a silently-wrong accept.

use fitq::coordinator::analysis::{self, AnalysisError};
use fitq::coordinator::evaluator::{ConfigFailure, ConfigOutcome, StudyResult};
use fitq::coordinator::service::parse_request;
use fitq::coordinator::pipeline::codec::{
    decode_optrace, decode_sensitivity, decode_study, decode_trace, encode_optrace,
    encode_sensitivity, encode_study, encode_trace,
};
use fitq::coordinator::pipeline::{ArtifactCache, Hasher, LeaseRecord};
use fitq::coordinator::{ActRanges, Estimator, SensitivityReport, TraceResult};
use fitq::metrics::{Metric, SensitivityInputs};
use fitq::native::manifest::{load_str, ZooManifest};
use fitq::native::simd::Isa;
use fitq::native::trace::{OpAggregate, OpTraceReport, TracedOp};
use fitq::native::tune::Lowering;
use fitq::quant::BitConfig;

/// splitmix64 — the standard seeded mixer, deterministic across runs and
/// platforms, so any failure reproduces from the iteration number alone.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Apply one random byte-level mutation: flip, insert, delete, or
/// truncate. Never leaves the buffer unchanged (except the empty case).
fn mutate(bytes: &mut Vec<u8>, rng: &mut u64) {
    if bytes.is_empty() {
        bytes.push(splitmix64(rng) as u8);
        return;
    }
    let r = splitmix64(rng);
    let pos = (splitmix64(rng) as usize) % bytes.len();
    match r % 4 {
        0 => bytes[pos] ^= (splitmix64(rng) as u8) | 1,
        1 => bytes.insert(pos, splitmix64(rng) as u8),
        2 => {
            bytes.remove(pos);
        }
        _ => bytes.truncate(pos),
    }
}

fn zoo_seed_texts() -> Vec<String> {
    let dirs = [
        concat!(env!("CARGO_MANIFEST_DIR"), "/../zoo"),
        concat!(env!("CARGO_MANIFEST_DIR"), "/tests/corpus/manifests/good"),
    ];
    let mut texts = Vec::new();
    for dir in dirs {
        let mut paths: Vec<_> = std::fs::read_dir(dir)
            .unwrap_or_else(|e| panic!("reading {dir}: {e}"))
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        paths.sort();
        for p in paths {
            texts.push(std::fs::read_to_string(p).unwrap());
        }
    }
    assert!(texts.len() >= 7, "expected the zoo + good corpus as mutation seeds");
    texts
}

/// Manifest parser: ~6k mutated documents. Accepted outputs must also
/// survive the canonical round trip — a mutation that parses into a
/// manifest which fails `parse(to_json(m)) == m` would mean the parser
/// and serializer disagree about the accepted language.
#[test]
fn fuzz_manifest_parser_never_panics() {
    let seeds = zoo_seed_texts();
    let mut rng = 0x5EED_0001_u64;
    let mut accepted = 0_u64;
    for (si, seed) in seeds.iter().enumerate() {
        for _ in 0..850 {
            let mut bytes = seed.clone().into_bytes();
            let n_mut = 1 + (splitmix64(&mut rng) as usize) % 4;
            for _ in 0..n_mut {
                mutate(&mut bytes, &mut rng);
            }
            let text = String::from_utf8_lossy(&bytes);
            if let Ok(m) = load_str(&text) {
                accepted += 1;
                let re = ZooManifest::parse(&m.manifest.to_json())
                    .unwrap_or_else(|e| panic!("seed {si}: canonical form rejected: {e}"));
                assert_eq!(re, m.manifest, "seed {si}: round trip diverged after mutation");
            }
        }
    }
    // sanity: the loop actually exercised the accept path too (some
    // mutations — e.g. inside a layer name — keep the document valid)
    assert!(accepted > 0, "no mutated manifest ever parsed; mutator too destructive?");
}

/// Cache container: ~800 mutated entry files. Every load must be a miss
/// or return the original payload byte-for-byte — corruption degrades to
/// a recompute, never to wrong results.
#[test]
fn fuzz_cache_header_rejects_or_returns_original() {
    let dir = std::env::temp_dir().join(format!("fitq_fuzzcache_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = ArtifactCache::new(&dir).unwrap();
    let key = Hasher::new().u64(0xF1F1).finish();
    let payload: Vec<u8> = (0..257u16).map(|i| (i % 251) as u8).collect();
    let path = cache.store("trace", 1, &key, &payload).unwrap();
    let pristine = std::fs::read(&path).unwrap();

    let mut rng = 0x5EED_0002_u64;
    for i in 0..800 {
        let mut bytes = pristine.clone();
        let n_mut = 1 + (splitmix64(&mut rng) as usize) % 3;
        for _ in 0..n_mut {
            mutate(&mut bytes, &mut rng);
        }
        std::fs::write(&path, &bytes).unwrap();
        if let Some(got) = cache.load("trace", 1, &key) {
            assert_eq!(got, payload, "iteration {i}: corrupt entry validated with new bytes");
        }
    }
    // restore and confirm the pristine entry still hits
    std::fs::write(&path, &pristine).unwrap();
    assert_eq!(cache.load("trace", 1, &key), Some(payload));
    std::fs::remove_dir_all(&dir).ok();
}

/// Lease records: ~2k mutated lease files. The parser must error or
/// return the pristine record (the trailing self-digest covers every
/// byte) — so a mangled lease always reads as stale-and-reapable, never
/// as a live hold by a phantom pid/token/expiry.
#[test]
fn fuzz_lease_record_parser_errors_or_roundtrips() {
    let rec = LeaseRecord { pid: 4242, token: 0xDEAD_BEEF, expires_unix_ms: u64::MAX / 2 };
    let pristine = rec.encode();
    assert_eq!(LeaseRecord::parse(&pristine).unwrap(), rec);

    let mut rng = 0x5EED_0004_u64;
    for i in 0..2000 {
        let mut bytes = pristine.clone();
        let n_mut = 1 + (splitmix64(&mut rng) as usize) % 3;
        for _ in 0..n_mut {
            mutate(&mut bytes, &mut rng);
        }
        if let Ok(got) = LeaseRecord::parse(&bytes) {
            // a pair of mutations can cancel out; anything else must fail
            assert_eq!(got, rec, "iteration {i}: mutated lease accepted with different fields");
        }
    }
}

/// Search-service request decoder: ~6k mutated request lines. The
/// decoder is the fail-closed front door of `fitq serve` — it must
/// return a typed `ProtocolError` or a valid `Request` for any byte
/// salad, never panic. Accepted mutants must themselves be stable:
/// parsing the same line twice yields the same request (the decoder is
/// a pure function of the line — any nondeterminism here would break
/// the service's bit-identity contract).
#[test]
fn fuzz_request_decoder_errors_or_parses() {
    let seeds = [
        r#"{"method":"ping"}"#.to_string(),
        r#"{"method":"stats"}"#.to_string(),
        r#"{"method":"score","study":{"model":"cnn_mnist","fp_epochs":1,"seed":0},"configs":[{"w":[8,4,3],"a":[6,3]}]}"#
            .to_string(),
        r#"{"method":"search","study":{"model":"cnn_mnist","fp_epochs":30,"seed":7,"trace":{"batch":16,"tol":0.01,"min_iters":8,"max_iters":200,"seed":3}},"mode":"random","samples":100000,"seed":1,"shards":16,"stream":true}"#
            .to_string(),
        r#"{"method":"search","study":{"model":"cnn_mnist","fp_epochs":1,"seed":0},"mode":"greedy","budget_ratio":0.15}"#
            .to_string(),
        r#"{"method":"pareto","study":{"model":"cnn_mnist","fp_epochs":1,"seed":0},"configs":[{"w":[8],"a":[]},{"w":[3],"a":[]}],"shards":2,"stream":false}"#
            .to_string(),
    ];
    let mut rng = 0x5EED_0005_u64;
    let mut accepted = 0u64;
    for (si, seed) in seeds.iter().enumerate() {
        for _ in 0..1000 {
            let mut bytes = seed.clone().into_bytes();
            let n_mut = 1 + (splitmix64(&mut rng) as usize) % 4;
            for _ in 0..n_mut {
                mutate(&mut bytes, &mut rng);
            }
            let text = String::from_utf8_lossy(&bytes);
            if let Ok(req) = parse_request(&text) {
                accepted += 1;
                let again = parse_request(&text)
                    .unwrap_or_else(|e| panic!("seed {si}: accept was not stable: {e}"));
                assert_eq!(again, req, "seed {si}: decoder is not a pure function");
            }
        }
    }
    // mutations inside string values / digits keep many lines valid
    assert!(accepted > 0, "no mutated request ever parsed; mutator too destructive?");
}

fn sample_trace() -> TraceResult {
    TraceResult {
        estimator: Estimator::Hutchinson,
        w_traces: vec![1.5, -2.25, 0.0],
        a_traces: vec![3.5],
        w_std_errors: vec![0.1, 0.2, 0.3],
        iterations: 42,
        iter_time_s: 0.0125,
        norm_variance: 7.75,
        history_total: vec![1.0, 1.25, 1.5],
    }
}

fn sample_sensitivity() -> SensitivityReport {
    SensitivityReport {
        inputs: SensitivityInputs {
            w_traces: vec![10.0, 2.0],
            a_traces: vec![4.0],
            w_lo: vec![-1.0, -0.5],
            w_hi: vec![1.0, 0.5],
            a_lo: vec![0.0],
            a_hi: vec![6.0],
            bn_gamma: vec![Some(1.0), None],
        },
        act: ActRanges { lo: vec![0.0], hi: vec![5.5] },
        trace: sample_trace(),
    }
}

fn sample_study() -> StudyResult {
    StudyResult {
        model: "cnn_mnist".into(),
        fp_test_score: 0.91,
        outcomes: vec![ConfigOutcome {
            cfg: BitConfig { bits_w: vec![8, 4], bits_a: vec![3] },
            metrics: vec![(Metric::Fit, Some(0.5)), (Metric::Bn, None)],
            test_score: 0.8,
            train_score: 0.85,
            mean_bits: 5.0,
        }],
        sens: sample_sensitivity(),
        correlations: vec![(Metric::Fit, Some(0.86))],
        failures: vec![ConfigFailure {
            index: 1,
            label: "w[2,2] a[2]".into(),
            panicked: true,
            error: "job 1 panicked".into(),
        }],
    }
}

/// Binary codec: ~3k mutated payloads across the three kinds. Decoders
/// must return `Err` or a value whose re-encoding is itself decodable —
/// no panic, no unbounded allocation (the length-prefix guard).
#[test]
fn fuzz_codec_decoders_error_or_produce_valid_values() {
    let kinds: Vec<(&str, Vec<u8>)> = vec![
        ("trace", encode_trace(&sample_trace())),
        ("sensitivity", encode_sensitivity(&sample_sensitivity())),
        ("study", encode_study(&sample_study())),
    ];
    let mut rng = 0x5EED_0003_u64;
    for (kind, pristine) in &kinds {
        for i in 0..1000 {
            let mut bytes = pristine.clone();
            let n_mut = 1 + (splitmix64(&mut rng) as usize) % 4;
            for _ in 0..n_mut {
                mutate(&mut bytes, &mut rng);
            }
            match *kind {
                "trace" => {
                    if let Ok(t) = decode_trace(&bytes) {
                        decode_trace(&encode_trace(&t))
                            .unwrap_or_else(|e| panic!("{kind} {i}: re-encode broke: {e}"));
                    }
                }
                "sensitivity" => {
                    if let Ok(s) = decode_sensitivity(&bytes) {
                        decode_sensitivity(&encode_sensitivity(&s))
                            .unwrap_or_else(|e| panic!("{kind} {i}: re-encode broke: {e}"));
                    }
                }
                _ => {
                    if let Ok(s) = decode_study(&bytes) {
                        decode_study(&encode_study(&s))
                            .unwrap_or_else(|e| panic!("{kind} {i}: re-encode broke: {e}"));
                    }
                }
            }
        }
    }
}

fn sample_optrace() -> OpTraceReport {
    OpTraceReport {
        model: "cnn_mnist".into(),
        workload: "train_epoch".into(),
        threads: 2,
        rows: vec![
            OpAggregate {
                op: TracedOp::ConvFwd,
                layer: "conv0".into(),
                variant: Some((Isa::Sse2, Lowering::Im2col)),
                width: 8,
                shape: "b32 16x16 1->8".into(),
                calls: 30,
                elems_read: 260_000,
                elems_written: 61_440,
                flops: 35_389_440,
                wall_ns: 4_200_000,
            },
            OpAggregate {
                op: TracedOp::Relu,
                layer: "conv0".into(),
                variant: None,
                width: 0,
                shape: "b32 16x16 c8".into(),
                calls: 30,
                elems_read: 61_440,
                elems_written: 61_440,
                flops: 61_440,
                wall_ns: 90_000,
            },
            OpAggregate {
                op: TracedOp::AdamStep,
                layer: "opt".into(),
                variant: None,
                width: 0,
                shape: "n6138".into(),
                calls: 10,
                elems_read: 24_552,
                elems_written: 18_414,
                flops: 73_656,
                wall_ns: 50_000,
            },
        ],
    }
}

/// Trace-report input path: ~2k mutations over the `optrace` decoder and
/// the bench-peaks parser. Both are fail-closed front doors of
/// `fitq trace-report`: every mutant must yield a typed error or a valid
/// value that survives the rest of the analysis pipeline (re-encode /
/// cost-report render) — never a panic.
#[test]
fn fuzz_optrace_decoder_and_bench_parser_never_panic() {
    let mut rng = 0x5EED_0006_u64;

    // half the budget: the binary optrace decoder
    let pristine = encode_optrace(&sample_optrace());
    for i in 0..1000 {
        let mut bytes = pristine.clone();
        let n_mut = 1 + (splitmix64(&mut rng) as usize) % 4;
        for _ in 0..n_mut {
            mutate(&mut bytes, &mut rng);
        }
        if let Ok(t) = decode_optrace(&bytes) {
            let re = decode_optrace(&encode_optrace(&t))
                .unwrap_or_else(|e| panic!("optrace {i}: re-encode broke: {e}"));
            assert_eq!(re, t, "optrace {i}: re-encode round trip diverged");
        }
    }

    // other half: the bench JSON parser + the report derivation it feeds,
    // seeded from the committed bench file trace-report actually reads
    let bench_seed = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_kernels.json"
    ))
    .expect("committed bench file");
    let trace = sample_optrace();
    let mut accepted = 0u64;
    for i in 0..1000 {
        let mut bytes = bench_seed.clone().into_bytes();
        let n_mut = 1 + (splitmix64(&mut rng) as usize) % 4;
        for _ in 0..n_mut {
            mutate(&mut bytes, &mut rng);
        }
        let text = String::from_utf8_lossy(&bytes);
        match analysis::parse_bench_kernels(&text) {
            Ok(peaks) => {
                accepted += 1;
                // an accepted mutant must carry through the whole report
                // path without panicking
                let report = analysis::cost_report(&trace, &peaks)
                    .unwrap_or_else(|e| panic!("bench {i}: report failed on accepted peaks: {e}"));
                let _ = analysis::render_text(&report);
                let _ = analysis::render_json(&report);
            }
            Err(e) => assert!(
                matches!(e, AnalysisError::BenchParse(_) | AnalysisError::BenchSchema(_)),
                "bench {i}: unexpected error kind {:?}",
                e.kind()
            ),
        }
    }
    // mutations outside the "kernels" array (status text, train_epoch
    // rows) keep the document valid for the peaks parser
    assert!(accepted > 0, "no mutated bench file ever parsed; mutator too destructive?");
}

/// `AnalysisError::kind()` strings are a stable API (this harness and
/// the CLI lean on them); pin the full set, alongside the manifest
/// parser's pin in `tests/manifest_validation.rs`.
#[test]
fn analysis_error_kinds_are_stable() {
    let kinds = [
        AnalysisError::BenchParse(String::new()).kind(),
        AnalysisError::BenchSchema(String::new()).kind(),
        AnalysisError::TraceDecode(String::new()).kind(),
        AnalysisError::EmptyTrace.kind(),
    ];
    assert_eq!(kinds, ["bench_parse", "bench_schema", "trace_decode", "empty_trace"]);
}
