//! Kernel dispatch & autotuning contracts (DESIGN.md "Kernel dispatch
//! & autotuning"):
//!
//! - `FITQ_NATIVE_KERNEL` parses fail-closed: unknown or unavailable
//!   variants are hard errors, never silent fallbacks.
//! - The tuner's route table persists through the artifact cache under
//!   the host fingerprint and round-trips exactly.
//! - Concurrent resolvers tune exactly once (lease coordination); the
//!   losers adopt the winner's published table.
//! - A crash between winning the tuning lease and publishing the table
//!   (the `tuner.publish.fail` injection site) degrades that resolver to
//!   an unpersisted local table and leaves the cache clean for the next.
//! - Kernel-variant selection never enters any pipeline stage digest:
//!   tuned hosts and forced-scalar hosts share cache entries, which is
//!   only sound because every variant is bit-identical (pinned op-level
//!   and whole-net by `tests/native_gemm.rs`, and through the `Runtime`
//!   dispatch path below).

use std::path::PathBuf;
use std::sync::Mutex;

use fitq::coordinator::pipeline::fault::{self, site, FaultPlan};
use fitq::coordinator::pipeline::{stages, ArtifactCache};
use fitq::coordinator::{ModelState, StudyOptions, TraceOptions};
use fitq::data::{EpochBatch, SynthClass};
use fitq::native::simd::{self, Isa};
use fitq::native::tune::{self, KernelMode, Resolution};
use fitq::runtime::{Arg, Runtime};

/// Serializes the tests in this binary that mutate process environment
/// (`FITQ_NATIVE_KERNEL`, `FITQ_RESULTS`) — cargo runs tests in threads.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fitq_kdisp_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn kernel_mode_parses_fail_closed() {
    assert_eq!(KernelMode::parse("auto").unwrap(), KernelMode::Auto);
    for isa in Isa::detected() {
        assert_eq!(KernelMode::parse(isa.name()).unwrap(), KernelMode::Forced(isa));
    }
    // scalar is always available, on every arch
    assert_eq!(KernelMode::parse("scalar").unwrap(), KernelMode::Forced(Isa::Scalar));
    assert!(KernelMode::parse("").is_err(), "empty value is an error");
    assert!(KernelMode::parse("avx512").is_err(), "unknown variant is an error");
    assert!(KernelMode::parse("AUTO-ish").is_err());
    // a variant that exists in the registry but not on this host must be
    // rejected too — running "neon" on x86 silently as scalar would be a
    // silent fallback
    for isa in simd::ALL {
        if !isa.available() {
            let err = KernelMode::parse(isa.name()).unwrap_err().to_string();
            assert!(
                err.contains(isa.name()),
                "unavailable {isa} must be named in the error: {err}"
            );
        }
    }
}

#[test]
fn kernel_mode_from_env_defaults_to_auto() {
    let _env = ENV_LOCK.lock().unwrap();
    std::env::remove_var("FITQ_NATIVE_KERNEL");
    assert_eq!(KernelMode::from_env().unwrap(), KernelMode::Auto);
    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    assert_eq!(KernelMode::from_env().unwrap(), KernelMode::Forced(Isa::Scalar));
    std::env::set_var("FITQ_NATIVE_KERNEL", "definitely-not-a-kernel");
    assert!(KernelMode::from_env().is_err(), "typos must fail, not fall back");
    std::env::remove_var("FITQ_NATIVE_KERNEL");
}

#[test]
fn tuner_table_persists_and_round_trips() {
    let dir = tmp("persist");
    let cache = ArtifactCache::new(dir.join("cache")).unwrap();
    let (t1, how1) = tune::resolve_at(&cache, 1);
    assert_eq!(how1, Resolution::TunedPublished, "first resolver tunes and publishes");
    let key = tune::host_fingerprint(1);
    assert!(
        cache.entry_path(tune::TUNER_KIND, &key).exists(),
        "published table must be a cache entry under the host fingerprint"
    );
    let (t2, how2) = tune::resolve_at(&cache, 1);
    assert_eq!(how2, Resolution::CacheHit, "second resolver hits the stored table");
    assert_eq!(t1, t2, "the table round-trips through the codec exactly");
    assert!(!t1.measurements.is_empty(), "tuned tables carry their measurements");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression for the tune-at-the-wrong-budget bug: the
/// micro-benchmarks now run at the intra-op budget the `ExecCtx` will
/// actually use, so a table tuned at `threads=1` must not be served to a
/// `threads=4` resolver — the persisted-table key (the host fingerprint)
/// carries the budget.
#[test]
fn route_table_is_keyed_per_thread_budget() {
    let dir = tmp("budget");
    let cache = ArtifactCache::new(dir.join("cache")).unwrap();
    let (_, how1) = tune::resolve_at(&cache, 1);
    assert_eq!(how1, Resolution::TunedPublished);
    let (_, how4) = tune::resolve_at(&cache, 4);
    assert_eq!(
        how4,
        Resolution::TunedPublished,
        "a different thread budget must re-tune, not adopt the serial table"
    );
    assert_ne!(
        tune::host_fingerprint(1),
        tune::host_fingerprint(4),
        "the budget must be part of the persisted-table fingerprint"
    );
    for threads in [1usize, 4] {
        assert!(
            cache.entry_path(tune::TUNER_KIND, &tune::host_fingerprint(threads)).exists(),
            "threads={threads} table must persist under its own key"
        );
        let (_, how) = tune::resolve_at(&cache, threads);
        assert_eq!(how, Resolution::CacheHit, "threads={threads} re-resolve hits its table");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_resolvers_tune_exactly_once() {
    // hold an (empty) fault scope for the whole test: it owns the
    // process-global fault lock, so the publish-fault drill below can
    // never interleave its armed plan with our resolvers
    let _scope = fault::scoped(FaultPlan::default());
    let dir = tmp("race");
    let outcomes: Vec<(tune::RouteTable, Resolution)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let root = dir.join("cache");
                s.spawn(move || {
                    let cache = ArtifactCache::new(root).unwrap();
                    tune::resolve_at(&cache, 1)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let published =
        outcomes.iter().filter(|(_, how)| *how == Resolution::TunedPublished).count();
    assert_eq!(published, 1, "exactly one resolver may tune and publish: {outcomes:?}");
    for (table, how) in &outcomes {
        assert_ne!(*how, Resolution::TunedUnpersisted, "nobody may time out or fail");
        if *how != Resolution::TunedPublished {
            assert!(
                matches!(how, Resolution::PeerPublished | Resolution::CacheHit),
                "losers adopt the winner's table: {how:?}"
            );
        }
        assert!(!table.measurements.is_empty(), "adopted tables carry the winner's measurements");
    }
    let first = &outcomes[0].0;
    for (table, _) in &outcomes[1..] {
        assert_eq!(table, first, "all resolvers must agree on one table");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tuner_publish_fault_recovers_cleanly() {
    let dir = tmp("fault");
    let cache = ArtifactCache::new(dir.join("cache")).unwrap();
    let key = tune::host_fingerprint(1);
    {
        let scope = fault::scoped(FaultPlan::single(site::TUNER_PUBLISH_FAIL));
        let (table, how) = tune::resolve_at(&cache, 1);
        assert_eq!(scope.fired(site::TUNER_PUBLISH_FAIL), 1, "the site must be reached");
        assert_eq!(
            how,
            Resolution::TunedUnpersisted,
            "a publish crash degrades to the local table, not an error"
        );
        assert!(!table.measurements.is_empty(), "the local table is still fully tuned");
        assert!(
            !cache.entry_path(tune::TUNER_KIND, &key).exists(),
            "the crashed publish must not leave a cache entry"
        );
        assert!(
            !cache.lease_path(tune::TUNER_KIND, &key).exists(),
            "the crashed publish must not wedge the tuning lease"
        );
    }
    // fault disarmed: the next resolver finds a clean cache and publishes
    let (_, how) = tune::resolve_at(&cache, 1);
    assert_eq!(how, Resolution::TunedPublished, "recovery tunes and publishes normally");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stage_keys_exclude_kernel_mode() {
    let _env = ENV_LOCK.lock().unwrap();
    let rt = Runtime::native().unwrap();
    let mm = rt.model("cnn_mnist").unwrap().clone();
    let keys = || {
        (
            stages::train_fp_key("native", &mm, 3, 0),
            stages::sensitivity_key("native", &mm, 3, 0, &TraceOptions::default()),
            stages::study_key("native", &mm, &StudyOptions::default()),
        )
    };
    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    let scalar_keys = keys();
    std::env::set_var("FITQ_NATIVE_KERNEL", "auto");
    let auto_keys = keys();
    std::env::remove_var("FITQ_NATIVE_KERNEL");
    assert_eq!(
        scalar_keys, auto_keys,
        "kernel-variant selection must never enter a stage digest: a tuned \
         host and a forced-scalar host share cache entries bit-for-bit"
    );
    assert_eq!(scalar_keys, keys(), "and unset (auto) agrees too");
}

/// One optimizer epoch through the real `Runtime` dispatch path under
/// every `FITQ_NATIVE_KERNEL` setting this host supports, serial and
/// threaded: identical bits everywhere. This is the end-to-end guarantee
/// that makes the digest-exclusion above sound.
#[test]
fn train_epoch_bit_identical_across_forced_env_variants() {
    let _env = ENV_LOCK.lock().unwrap();
    let dir = tmp("train");
    // auto mode resolves its route table under the results root
    std::env::set_var("FITQ_RESULTS", &dir);

    let epoch_bits = |threads: usize| -> Vec<u32> {
        let rt = Runtime::native_with_threads(threads).unwrap();
        let mm = rt.model("cnn_mnist").unwrap().clone();
        let exe = rt.load("cnn_mnist", "train_epoch").unwrap();
        let st = ModelState::init(&rt, "cnn_mnist", 3).unwrap();
        let ds = SynthClass::synmnist(3);
        let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
        let out = exe
            .run(&[
                Arg::F32(&st.params),
                Arg::F32(&st.m),
                Arg::F32(&st.v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap();
        let mut bits: Vec<u32> = out.f32("params").unwrap().iter().map(|v| v.to_bits()).collect();
        bits.push(out.scalar("loss").unwrap().to_bits());
        bits
    };

    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    let baseline = epoch_bits(1);
    let mut modes: Vec<String> = Isa::detected().into_iter().map(|i| i.name().into()).collect();
    modes.push("auto".into());
    for mode in &modes {
        std::env::set_var("FITQ_NATIVE_KERNEL", mode);
        for threads in [1usize, 4] {
            assert_eq!(
                epoch_bits(threads),
                baseline,
                "FITQ_NATIVE_KERNEL={mode} threads={threads} must replay the scalar bits"
            );
        }
    }
    std::env::remove_var("FITQ_NATIVE_KERNEL");
    std::env::remove_var("FITQ_RESULTS");
    let _ = std::fs::remove_dir_all(&dir);
}
