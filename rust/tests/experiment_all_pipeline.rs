//! Registry walk over real artifacts: shared stages compute exactly once
//! per run, and a warm rerun reproduces the cold run's result files
//! byte-for-byte from cache. Skipped on a fresh checkout (no artifacts).
//!
//! This file holds a single test because it owns the process-wide
//! `FITQ_RESULTS` environment variable for report emission.

use fitq::coordinator::pipeline::{registry, ExpOptions, Pipeline};
use fitq::runtime::Runtime;

mod common;

#[test]
fn experiment_walk_counts_stages_once_and_reruns_byte_identical() {
    let Some(root) = common::artifact_root() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::new(root).expect("runtime");
    let results = std::env::temp_dir().join(format!("fitq_expall_{}", std::process::id()));
    std::fs::remove_dir_all(&results).ok();
    std::env::set_var("FITQ_RESULTS", &results);

    // a tiny two-study table2: two FP checkpoints, two sensitivity
    // reports, two study sweeps — and nothing computed twice
    let o = ExpOptions {
        seed: 6,
        configs: Some(3),
        fp_epochs: Some(2),
        qat_epochs: Some(1),
        eval_n: Some(64),
        only: vec!["C".into(), "D".into()],
        ..Default::default()
    };
    let specs = vec![registry::find("table2").expect("registered")];

    let pipe = Pipeline::new(&results).expect("pipeline");
    registry::run_all(&rt, &pipe, &specs, &o).expect("cold walk");
    let c = pipe.counters();
    assert_eq!(c.train_fp_computed(), 2, "one FP training per (model, seed, epochs)");
    assert_eq!(c.sensitivity_computed(), 2, "one sensitivity gather per study");
    assert_eq!(c.study_computed(), 2, "one sweep per study");

    let read = |name: &str| std::fs::read(results.join(name)).unwrap_or_default();
    let cold: Vec<(String, Vec<u8>)> = ["table2.csv", "table2.md", "fig3_expC.csv", "fig3_expD.csv"]
        .iter()
        .map(|n| (n.to_string(), read(n)))
        .collect();
    for (name, bytes) in &cold {
        assert!(!bytes.is_empty(), "cold run must write {name}");
    }

    // warm walk with a fresh pipeline (cross-process shape): zero
    // computations, byte-identical reports
    let pipe2 = Pipeline::new(&results).expect("pipeline 2");
    registry::run_all(&rt, &pipe2, &specs, &o).expect("warm walk");
    let c2 = pipe2.counters();
    assert_eq!(
        (c2.train_fp_computed(), c2.sensitivity_computed(), c2.study_computed()),
        (0, 0, 0),
        "warm walk must be pure cache reads"
    );
    for (name, bytes) in &cold {
        assert_eq!(
            &read(name),
            bytes,
            "{name} must be byte-identical across cold and warm walks"
        );
    }

    std::env::remove_var("FITQ_RESULTS");
    std::fs::remove_dir_all(&results).ok();
}
