//! Shared test support: one backend-resolution rule for every
//! integration suite, so they cannot drift apart.

// not every test binary uses every helper
#![allow(dead_code)]

use fitq::runtime::Runtime;

/// The artifact root this checkout carries, if any: `make artifacts`
/// writes to the repo root (`--out ../artifacts` from `python/`), and a
/// package-local `rust/artifacts` is honored too.
pub fn artifact_root() -> Option<&'static str> {
    [
        concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"),
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    ]
    .into_iter()
    .find(|root| std::path::Path::new(root).join("manifest.json").exists())
}

/// PJRT over real artifacts when present, else the zero-setup native
/// backend — announcing the choice so a silently-missing artifact tree
/// is visible in test output.
pub fn runtime() -> Runtime {
    match artifact_root() {
        Some(root) => Runtime::new(root).expect("pjrt runtime"),
        None => {
            eprintln!("no artifacts found: running on the native backend");
            Runtime::native().expect("native runtime")
        }
    }
}
