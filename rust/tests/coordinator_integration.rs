//! Integration: the full coordinator pipeline over a real backend —
//! PJRT when artifacts are present, else the native interpreter, so the
//! train/trace/QAT/eval loop is exercised on every checkout. Tests that
//! need PJRT-only entries (Hutchinson, scale models) still skip without
//! artifacts.

use fitq::coordinator::{
    dataset_for, gather, Estimator, ModelState, TraceEngine, TraceOptions, Trainer,
};
use fitq::data::EvalSet;
use fitq::metrics::{fit, Metric};
use fitq::quant::BitConfig;
use fitq::runtime::Runtime;

mod common;

fn runtime() -> Option<Runtime> {
    Some(common::runtime())
}

#[test]
fn training_reduces_loss_and_beats_chance() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist";
    let ds = dataset_for(&rt, model, 1).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 1).unwrap();
    let losses = trainer.train(&mut st, 12).unwrap();
    // 0.7: headroom over the observed ~0.54 ratio at this seed — the
    // trajectory is chaotic enough that cross-backend drift moves it
    assert!(losses.last().unwrap() < &(0.7 * losses[0]), "{losses:?}");
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let r = trainer.evaluate(&st, &ev).unwrap();
    assert!(r.score > 0.3, "acc {} must beat 10-class chance", r.score);
}

#[test]
fn deterministic_replay() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist";
    let run = || {
        let ds = dataset_for(&rt, model, 7).unwrap();
        let mut trainer = Trainer::new(&rt, ds.as_ref());
        let mut st = ModelState::init(&rt, model, 7).unwrap();
        trainer.train(&mut st, 3).unwrap();
        st.params
    };
    assert_eq!(run(), run(), "same seeds must replay bit-exactly");
}

#[test]
fn qat_lower_bits_hurt_more() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist";
    let mm = rt.model(model).unwrap().clone();
    let ds = dataset_for(&rt, model, 2).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 2).unwrap();
    trainer.train(&mut st, 15).unwrap();
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    // capped trace run: the FIT/PTQ ordering assertions need converged-ish
    // traces, not the paper's full tol=0.01 protocol
    let opt = TraceOptions { batch: 32, tol: 0.05, min_iters: 8, max_iters: 150, seed: 2 };
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, opt).unwrap();

    let q8 = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 8);
    let q3 = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 3);
    // FIT predicts 8-bit safer than 3-bit
    assert!(fit(&sens.inputs, &q8) < fit(&sens.inputs, &q3));
    // and measured (no fine-tune) quantized eval agrees
    let a8 = trainer.evaluate_q(&st, &ev, &q8, &sens.act).unwrap();
    let a3 = trainer.evaluate_q(&st, &ev, &q3, &sens.act).unwrap();
    let fp = trainer.evaluate(&st, &ev).unwrap();
    assert!(a8.score >= a3.score, "8bit {} vs 3bit {}", a8.score, a3.score);
    assert!((a8.score - fp.score).abs() < 0.1, "8-bit PTQ near-lossless");
}

#[test]
fn ef_trace_converges_with_tolerance() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist";
    let ds = dataset_for(&rt, model, 3).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 3).unwrap();
    trainer.train(&mut st, 8).unwrap();
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let opts = |tol: f64| TraceOptions { batch: 32, tol, min_iters: 8, max_iters: 150, seed: 3 };
    let loose = engine
        .run(model, &st.params, Estimator::EmpiricalFisher, opts(0.1))
        .unwrap();
    let tight = engine
        .run(model, &st.params, Estimator::EmpiricalFisher, opts(0.03))
        .unwrap();
    assert!(tight.iterations >= loose.iterations, "tighter tol needs more iters");
    assert!(loose.w_traces.iter().all(|&t| t > 0.0));
    // trace estimates must agree across tolerances within a coarse band
    for (a, b) in loose.w_traces.iter().zip(&tight.w_traces) {
        assert!((a - b).abs() / b.max(1e-9) < 0.5, "{a} vs {b}");
    }
}

#[test]
fn hutchinson_and_ef_agree_on_block_ranking() {
    let Some(rt) = runtime() else { return };
    // scale models carry both estimators — PJRT-only (the native backend
    // implements the study set, and EF is the paper's production path)
    let model = "cnn_s";
    if rt.model(model).is_err() {
        eprintln!("skipping: scale models need PJRT artifacts");
        return;
    }
    let ds = dataset_for(&rt, model, 4).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 4).unwrap();
    trainer.train(&mut st, 10).unwrap();
    let engine = TraceEngine::new(&rt, ds.as_ref());
    let ef = engine
        .run(model, &st.params, Estimator::EmpiricalFisher, TraceOptions::fixed_iters(32, 60, 1))
        .unwrap();
    let h = engine
        .run(model, &st.params, Estimator::Hutchinson, TraceOptions::fixed_iters(32, 60, 1))
        .unwrap();
    let rho = fitq::stats::spearman(&ef.w_traces, &h.w_traces);
    assert!(rho > 0.7, "EF/Hessian block ranking must agree, rho={rho}");
}

#[test]
fn metric_zoo_evaluates_on_gathered_inputs() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist_bn";
    let mm = rt.model(model).unwrap().clone();
    let ds = dataset_for(&rt, model, 5).unwrap();
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 5).unwrap();
    trainer.train(&mut st, 6).unwrap();
    let ev = EvalSet::materialize(ds.as_ref(), 256);
    let opt = TraceOptions { batch: 32, tol: 0.05, min_iters: 8, max_iters: 60, seed: 5 };
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, opt).unwrap();
    assert!(sens.inputs.has_bn(), "bn model must expose gammas");
    let cfg = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);
    for m in Metric::ALL {
        let v = m.eval(&sens.inputs, &cfg).expect("applies on BN model");
        assert!(v.is_finite() && v >= 0.0, "{m:?} -> {v}");
    }
    // activation ranges calibrated from ReLU outputs are non-negative
    assert!(sens.act.lo.iter().all(|&l| l >= 0.0));
    assert!(sens.act.lo.iter().zip(&sens.act.hi).all(|(l, h)| h > l));
}
