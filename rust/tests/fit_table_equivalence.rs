//! Pure-Rust equivalence suite for the table-driven scoring engine — no
//! artifacts, no PJRT, runs everywhere tier-1 runs.
//!
//! The contract under test: `FitTable::score`, the heap greedy and the
//! table-driven exact allocator are *bit-identical* to the naive reference
//! paths (`metrics::fit`, `greedy_allocate_naive`, brute-force
//! enumeration) on seeded instances. Instances are integer-derived so the
//! construction is exactly reproducible; the greedy/exact expectations
//! were additionally cross-checked against an independent IEEE-f64
//! simulation of both algorithms.

use fitq::coordinator::{
    exact_allocate, greedy_allocate, greedy_allocate_naive, pareto_front, pareto_front_scores,
    score,
};
use fitq::metrics::{fit, FitTable, PackedConfig, SensitivityInputs};
use fitq::quant::{model_bits, BitConfig, BitConfigSampler, PRECISIONS};

/// Deterministic pseudo-random instance `k` with integer-derived f64
/// values (exact in IEEE arithmetic). `k % 3 == 0` plants a zero-range
/// weight block; `la == 0` exercises empty activation lists.
fn det_instance(k: u64, lw: usize, la: usize) -> (SensitivityInputs, Vec<usize>) {
    let h = |i: u64, m: u64| {
        k.wrapping_mul(0x9e37_79b9).wrapping_add(i.wrapping_mul(0x85eb_ca6b)) % m
    };
    let w_traces: Vec<f64> = (0..lw as u64).map(|i| 0.05 + h(i, 997) as f64 / 31.0).collect();
    let w_hi: Vec<f64> = (0..lw as u64).map(|i| 0.1 + h(i + 100, 613) as f64 / 100.0).collect();
    let mut w_lo: Vec<f64> = w_hi.iter().map(|&x| -x).collect();
    if k % 3 == 0 && lw > 1 {
        w_lo[1] = w_hi[1]; // zero-range block: contributes 0 at any precision
    }
    let a_traces: Vec<f64> =
        (0..la as u64).map(|i| 0.02 + h(i + 200, 401) as f64 / 53.0).collect();
    let a_hi: Vec<f64> = (0..la as u64).map(|i| 0.5 + h(i + 300, 211) as f64 / 29.0).collect();
    let sizes: Vec<usize> = (0..lw as u64).map(|i| 16 + h(i + 400, 2000) as usize).collect();
    let s = SensitivityInputs {
        bn_gamma: vec![None; lw],
        a_lo: vec![0.0; la],
        w_traces,
        w_lo,
        w_hi,
        a_traces,
        a_hi,
    };
    (s, sizes)
}

#[test]
fn table_score_matches_naive_fit_bit_for_bit() {
    for k in 1..13u64 {
        let lw = 1 + (k as usize) % 6;
        let la = (k as usize) % 4;
        let (s, sizes) = det_instance(k, lw, la);
        let table = FitTable::new(&s, &sizes, 3, &PRECISIONS);
        let mut sampler = BitConfigSampler::new(lw, la, &PRECISIONS, k);
        for cfg in sampler.take(32) {
            let p = table.pack(&cfg);
            assert_eq!(
                table.score(&p).to_bits(),
                fit(&s, &cfg).to_bits(),
                "k={k} {}",
                cfg.label()
            );
            assert_eq!(table.size_bits(&p), model_bits(&sizes, 3, &cfg));
        }
    }
}

#[test]
fn packed_config_round_trips() {
    for k in 1..8u64 {
        let lw = 1 + (k as usize) % 6;
        let la = (k as usize) % 4;
        let mut sampler = BitConfigSampler::new(lw, la, &PRECISIONS, 77 + k);
        for cfg in sampler.take(16) {
            let p = PackedConfig::from(&cfg);
            assert_eq!(BitConfig::from(&p), cfg);
            assert_eq!(p.n_weight_blocks(), lw);
            assert_eq!(p.n_act_blocks(), la);
        }
    }
}

#[test]
fn heap_greedy_matches_naive_reference() {
    for k in 1..25u64 {
        let lw = 2 + (k as usize) % 5;
        let la = (k as usize) % 4;
        let (s, sizes) = det_instance(k, lw, la);
        let full = model_bits(&sizes, 3, &BitConfig::uniform(lw, la, 8));
        for num in [95u64, 80, 70, 60, 50, 45, 40] {
            let budget = full * num / 100;
            let a = greedy_allocate_naive(&s, &sizes, 3, &PRECISIONS, budget);
            let b = greedy_allocate(&s, &sizes, 3, &PRECISIONS, budget);
            match (a, b) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.cfg, b.cfg, "k={k} num={num}");
                    assert_eq!(a.fit.to_bits(), b.fit.to_bits(), "k={k} num={num}");
                    assert_eq!(a.size_bits, b.size_bits, "k={k} num={num}");
                    assert!(b.size_bits <= budget, "k={k} num={num}");
                }
                (a, b) => panic!("feasibility disagrees at k={k} num={num}: {a:?} vs {b:?}"),
            }
        }
    }
}

#[test]
fn exact_allocator_matches_brute_force_bit_for_bit() {
    for k in [2u64, 5, 7, 11] {
        let lw = 3 + (k as usize) % 3; // 4^lw <= 1024: enumerable
        let la = (k as usize) % 3;
        let (s, sizes) = det_instance(k, lw, la);
        let full = model_bits(&sizes, 3, &BitConfig::uniform(lw, la, 8));
        for num in [80u64, 60, 45] {
            let budget = full * num / 100;
            let Some(e) = exact_allocate(&s, &sizes, 3, &PRECISIONS, budget) else {
                continue;
            };
            assert!(e.size_bits <= budget);
            let mut best = f64::INFINITY;
            for code in 0..PRECISIONS.len().pow(lw as u32) {
                let mut c = code;
                let mut bits_w = Vec::with_capacity(lw);
                for _ in 0..lw {
                    bits_w.push(PRECISIONS[c % PRECISIONS.len()]);
                    c /= PRECISIONS.len();
                }
                let cfg = BitConfig { bits_w, bits_a: vec![8; la] };
                if model_bits(&sizes, 3, &cfg) <= budget {
                    let f = fit(&s, &cfg);
                    if f < best {
                        best = f;
                    }
                }
            }
            assert_eq!(e.fit.to_bits(), best.to_bits(), "k={k} num={num}");
        }
    }
}

#[test]
fn batch_scores_are_jobs_invariant_and_match_struct_path() {
    let (s, sizes) = det_instance(4, 5, 2);
    let table = FitTable::new(&s, &sizes, 3, &PRECISIONS);
    let mut sampler = BitConfigSampler::new(5, 2, &PRECISIONS, 99);
    let configs = sampler.take(500);
    let packed: Vec<PackedConfig> = configs.iter().map(|c| table.pack(c)).collect();
    // replicate to force several pool chunks
    let packed: Vec<PackedConfig> = (0..20).flat_map(|_| packed.iter().cloned()).collect();
    let serial = table.score_batch(&packed, 1);
    for jobs in [2usize, 4, 0] {
        let got = table.score_batch(&packed, jobs);
        assert_eq!(got.len(), serial.len());
        for (g, r) in got.iter().zip(&serial) {
            assert_eq!(g.0.to_bits(), r.0.to_bits());
            assert_eq!(g.1, r.1);
        }
    }
    // and the pair stream agrees with the ScoredConfig path
    let pts: Vec<_> = configs.iter().map(|c| score(&s, &sizes, 3, c.clone())).collect();
    let pairs = table.score_batch(&packed[..configs.len()], 1);
    assert_eq!(
        pareto_front(&pts),
        pareto_front_scores(&pairs),
        "front must agree between struct and pair paths"
    );
}
