//! Op-trace contracts (DESIGN.md "Op tracing & analysis"):
//!
//! - Tracing is an *observer*: with `FITQ_TRACE_OPS` armed, every output
//!   — losses, trained parameters, serialized study bytes — is
//!   bit-identical to an untraced run, at `jobs ∈ {1, 4}`.
//! - Tracing never enters a pipeline stage digest: every stage key (and
//!   the `optrace` key itself) is byte-identical whether or not the
//!   profiler is armed.
//! - The trace counters (calls, elements, FLOPs, shapes, variants) are
//!   pure functions of the workload: deterministic across runs and
//!   across intra-op thread budgets. Wall clock is the *only*
//!   nondeterministic field, and [`OpTraceReport::normalized`] zeroes
//!   exactly it, making serialized traces byte-comparable.
//! - The `optrace` codec round-trips byte-exactly on real traces.

use std::path::PathBuf;
use std::sync::Mutex;

use fitq::coordinator::pipeline::codec::{decode_optrace, encode_optrace};
use fitq::coordinator::pipeline::stages::{
    optrace_key, sensitivity_key, study_key, train_fp_key,
};
use fitq::coordinator::{run_study, ModelState, Pipeline, StudyOptions, TraceOptions};
use fitq::data::{EpochBatch, SynthClass};
use fitq::native::trace::{OpTraceReport, TracedOp};
use fitq::runtime::{Arg, Runtime};

/// Serializes the tests in this binary that mutate process environment
/// (`FITQ_TRACE_OPS`, `FITQ_NATIVE_KERNEL`) — cargo runs tests in threads.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fitq_optrace_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One `train_epoch` through the real `Runtime` dispatch path: the
/// output bits (trained params + loss) and whatever trace the backend
/// accumulated. The profiler arms off `FITQ_TRACE_OPS` at runtime
/// construction, so the caller controls tracing via the env var.
fn epoch(threads: usize) -> (Vec<u32>, Option<OpTraceReport>) {
    let rt = Runtime::native_with_threads(threads).unwrap();
    let mm = rt.model("cnn_mnist").unwrap().clone();
    let exe = rt.load("cnn_mnist", "train_epoch").unwrap();
    let st = ModelState::init(&rt, "cnn_mnist", 3).unwrap();
    let ds = SynthClass::synmnist(3);
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    let out = exe
        .run(&[
            Arg::F32(&st.params),
            Arg::F32(&st.m),
            Arg::F32(&st.v),
            Arg::F32Scalar(0.0),
            Arg::F32(&eb.xs),
            Arg::I32(&eb.ys),
        ])
        .unwrap();
    let mut bits: Vec<u32> = out.f32("params").unwrap().iter().map(|v| v.to_bits()).collect();
    bits.push(out.scalar("loss").unwrap().to_bits());
    (bits, rt.op_trace())
}

/// Armed vs disarmed, serial and threaded: identical bits everywhere,
/// and the armed run actually collects a trace with the ops the model
/// dispatches. This is the observer guarantee the digest-exclusion rule
/// below rests on.
#[test]
fn tracing_does_not_change_train_epoch_bits() {
    let _env = ENV_LOCK.lock().unwrap();
    // forced-scalar routing: deterministic dispatch without a tuning
    // pass, and bit-identical to every other variant anyway
    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    std::env::remove_var("FITQ_TRACE_OPS");

    let (baseline, off_trace) = epoch(1);
    assert!(off_trace.is_none(), "disarmed backend must report no trace");
    assert_eq!(epoch(4).0, baseline, "threads=4 untraced must replay the bits");

    std::env::set_var("FITQ_TRACE_OPS", "1");
    for threads in [1usize, 4] {
        let (bits, trace) = epoch(threads);
        assert_eq!(bits, baseline, "threads={threads} traced run changed the output bits");
        let trace = trace.expect("armed backend must expose a trace");
        assert_eq!(trace.threads, threads as u32);
        assert!(!trace.rows.is_empty());
        for op in [
            TracedOp::ConvFwd,
            TracedOp::ConvBwdW,
            TracedOp::ConvBwdX,
            TracedOp::DenseFwd,
            TracedOp::DenseBwd,
            TracedOp::Relu,
            TracedOp::MaxPool,
            TracedOp::SoftmaxXent,
            TracedOp::AdamStep,
        ] {
            assert!(
                trace.rows.iter().any(|r| r.op == op),
                "train_epoch must trace {op:?}: {:?}",
                trace.rows.iter().map(|r| r.op).collect::<Vec<_>>()
            );
        }
        // tuned ops carry their routed variant, element-wise ops don't
        assert!(trace
            .rows
            .iter()
            .all(|r| (r.op as u8) < 5 || r.variant.is_none()));
        assert!(trace
            .rows
            .iter()
            .all(|r| (r.op as u8) >= 5 || r.variant.is_some()));
    }
    std::env::remove_var("FITQ_TRACE_OPS");
    std::env::remove_var("FITQ_NATIVE_KERNEL");
}

/// The digest-exclusion rule: arming the profiler changes no pipeline
/// stage key, and the `optrace` key itself hashes only
/// (backend, model layout, workload) — never threads or the switch.
#[test]
fn tracing_never_enters_stage_digests() {
    let _env = ENV_LOCK.lock().unwrap();
    let keys = || {
        let rt = Runtime::native().unwrap();
        let mm = rt.model("cnn_mnist").unwrap().clone();
        (
            train_fp_key("native", &mm, 3, 0),
            sensitivity_key("native", &mm, 3, 0, &TraceOptions::default()),
            study_key("native", &mm, &StudyOptions::default()),
            optrace_key("native", &mm, "train_epoch"),
        )
    };
    std::env::remove_var("FITQ_TRACE_OPS");
    let off = keys();
    std::env::set_var("FITQ_TRACE_OPS", "1");
    let on = keys();
    std::env::remove_var("FITQ_TRACE_OPS");
    assert_eq!(
        off, on,
        "the tracing switch must never reach a stage digest: traced and \
         untraced runs share every cache entry bit-for-bit"
    );
}

/// Counters are pure functions of the workload: two runs, and runs under
/// different intra-op budgets, agree on every field but wall clock —
/// and byte-for-byte once `normalized()` zeroes it.
#[test]
fn counters_deterministic_across_runs_and_thread_budgets() {
    let _env = ENV_LOCK.lock().unwrap();
    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    std::env::set_var("FITQ_TRACE_OPS", "1");
    let t_a = epoch(1).1.unwrap();
    let t_b = epoch(1).1.unwrap();
    let t_4 = epoch(4).1.unwrap();
    std::env::remove_var("FITQ_TRACE_OPS");
    std::env::remove_var("FITQ_NATIVE_KERNEL");

    assert_eq!(t_a.normalized(), t_b.normalized(), "re-run counters diverged");
    assert_eq!(
        encode_optrace(&t_a.normalized()),
        encode_optrace(&t_b.normalized()),
        "normalized serialized traces must be byte-identical across runs"
    );
    // the thread budget reaches the report header (it is honest metadata)
    // but never the per-op counters
    let mut t_4n = t_4.normalized();
    assert_eq!(t_4n.threads, 4);
    t_4n.threads = 1;
    assert_eq!(
        t_a.normalized(),
        t_4n,
        "intra-op threading must not change any counter, shape or variant"
    );
}

/// The `optrace` codec on a *real* trace: decode(encode(x)) == x, and
/// re-encoding reproduces the exact bytes (wall clock included — the
/// codec itself is lossless; normalization is only for comparisons).
#[test]
fn optrace_roundtrip_byte_exact_on_real_traces() {
    let _env = ENV_LOCK.lock().unwrap();
    std::env::set_var("FITQ_NATIVE_KERNEL", "scalar");
    std::env::set_var("FITQ_TRACE_OPS", "1");
    let mut report = epoch(1).1.unwrap();
    std::env::remove_var("FITQ_TRACE_OPS");
    std::env::remove_var("FITQ_NATIVE_KERNEL");

    report.model = "cnn_mnist".to_string();
    report.workload = "train_epoch".to_string();
    let bytes = encode_optrace(&report);
    let decoded = decode_optrace(&bytes).expect("decode real trace");
    assert_eq!(decoded, report, "decode must reproduce the report exactly");
    assert_eq!(encode_optrace(&decoded), bytes, "re-encode must reproduce the bytes");

    let norm = report.normalized();
    assert_eq!(
        decode_optrace(&encode_optrace(&norm)).unwrap(),
        norm,
        "and the normalized form round-trips too"
    );
}

/// The whole-pipeline observer guarantee: a full (miniature) study's
/// serialized bytes are identical untraced vs traced, at `jobs ∈ {1, 4}`
/// — tracing rides along through training, traces, sensitivity and the
/// config sweep without perturbing one bit of any of them.
#[test]
fn study_bytes_identical_with_tracing_at_jobs_1_and_4() {
    let _env = ENV_LOCK.lock().unwrap();
    let mut opt = StudyOptions {
        n_configs: 2,
        fp_epochs: 1,
        qat_epochs: 1,
        eval_n: 128,
        seed: 11,
        ..Default::default()
    };
    opt.trace.max_iters = 16;

    let study = |jobs: usize, tag: &str| -> Vec<u8> {
        let dir = tmp(&format!("study_{tag}"));
        let rt = Runtime::native_with_threads(1).unwrap();
        let pipe = Pipeline::new(&dir).expect("pipeline");
        let mut o = opt.clone();
        o.jobs = jobs;
        let mut s = run_study(&rt, &pipe, "cnn_mnist", &o).expect("study");
        std::fs::remove_dir_all(&dir).ok();
        // normalize the single wall-clock field (zoo_models.rs pattern)
        s.sens.trace.iter_time_s = 0.0;
        fitq::coordinator::pipeline::codec::encode_study(&s)
    };

    std::env::remove_var("FITQ_TRACE_OPS");
    let base = study(1, "off_j1");
    std::env::set_var("FITQ_TRACE_OPS", "1");
    let on_j1 = study(1, "on_j1");
    let on_j4 = study(4, "on_j4");
    std::env::remove_var("FITQ_TRACE_OPS");
    assert_eq!(on_j1, base, "jobs=1 traced study bytes diverged from untraced");
    assert_eq!(on_j4, base, "jobs=4 traced study bytes diverged from untraced");
}
