//! Artifact-cache coverage: round-trip + version-bump invalidation +
//! truncated-file fallback for every serialized stage type, plus the
//! cold-vs-warm `run_study` bit-identity and exactly-once stage
//! accounting the pipeline promises — run end-to-end on PJRT when
//! artifacts are present, else on the zero-setup native backend.

use fitq::coordinator::evaluator::{ConfigFailure, ConfigOutcome};
use fitq::coordinator::pipeline::{codec, ArtifactCache, Hasher, Pipeline};
use fitq::coordinator::{
    run_study, ActRanges, Estimator, ModelState, SensitivityReport, StudyOptions, StudyResult,
    TraceResult,
};
use fitq::metrics::{Metric, SensitivityInputs};
use fitq::quant::BitConfig;

mod common;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fitq_plc_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn sample_trace() -> TraceResult {
    TraceResult {
        estimator: Estimator::EmpiricalFisher,
        w_traces: vec![4.0, 1.5, 0.25],
        a_traces: vec![2.0, 0.5],
        w_std_errors: vec![0.01, 0.02, 0.03],
        iterations: 96,
        iter_time_s: 0.004,
        norm_variance: 0.15,
        history_total: vec![5.5, 5.75, 5.8],
    }
}

fn sample_sensitivity() -> SensitivityReport {
    SensitivityReport {
        inputs: SensitivityInputs {
            w_traces: vec![4.0, 1.5, 0.25],
            a_traces: vec![2.0, 0.5],
            w_lo: vec![-1.0, -0.5, -0.25],
            w_hi: vec![1.0, 0.5, 0.25],
            a_lo: vec![0.0, 0.0],
            a_hi: vec![6.0, 3.0],
            bn_gamma: vec![Some(1.0), Some(0.5), None],
        },
        act: ActRanges { lo: vec![0.0, 0.0], hi: vec![5.0, 2.5] },
        trace: sample_trace(),
    }
}

fn sample_study() -> StudyResult {
    StudyResult {
        model: "cnn_mnist".into(),
        fp_test_score: 0.9,
        outcomes: vec![
            ConfigOutcome {
                cfg: BitConfig { bits_w: vec![8, 4, 3], bits_a: vec![6, 6] },
                metrics: vec![(Metric::Fit, Some(0.125)), (Metric::Bn, None)],
                test_score: 0.82,
                train_score: 0.88,
                mean_bits: 5.4,
            },
            ConfigOutcome {
                cfg: BitConfig { bits_w: vec![3, 3, 3], bits_a: vec![3, 3] },
                metrics: vec![(Metric::Fit, Some(0.75)), (Metric::Bn, None)],
                test_score: 0.55,
                train_score: 0.6,
                mean_bits: 3.0,
            },
        ],
        sens: sample_sensitivity(),
        correlations: vec![(Metric::Fit, Some(0.86)), (Metric::Qr, None)],
        failures: vec![ConfigFailure {
            index: 2,
            label: "w[2,2,2] a[2,2]".into(),
            panicked: false,
            error: "qat diverged".into(),
        }],
    }
}

fn sample_state() -> ModelState {
    ModelState {
        model: "cnn_mnist".into(),
        params: vec![0.5, -1.25, 2.0],
        m: vec![0.1, 0.0, -0.1],
        v: vec![0.01, 0.02, 0.03],
        step: 17.0,
    }
}

/// Each stage type: store -> load -> decode must round trip bit-exactly,
/// a schema bump must miss, and a truncated entry must miss.
#[test]
fn every_stage_payload_roundtrips_and_invalidates() {
    let dir = tmp_dir("kinds");
    let cache = ArtifactCache::new(&dir).unwrap();

    // (kind, schema, payload, post-decode re-encode for bit-identity)
    let trace = sample_trace();
    let sens = sample_sensitivity();
    let study = sample_study();
    let state = sample_state();
    let cases: Vec<(&str, u32, Vec<u8>)> = vec![
        ("traces", codec::TRACE_SCHEMA, codec::encode_trace(&trace)),
        ("sensitivity", codec::SENSITIVITY_SCHEMA, codec::encode_sensitivity(&sens)),
        ("study", codec::STUDY_SCHEMA, codec::encode_study(&study)),
        ("train_fp", codec::CKPT_SCHEMA, state.to_bytes()),
    ];

    for (i, (kind, schema, payload)) in cases.iter().enumerate() {
        let key = Hasher::new().u64(i as u64).finish();
        let path = cache.store(kind, *schema, &key, payload).unwrap();

        // round trip
        let back = cache.load(kind, *schema, &key).unwrap();
        assert_eq!(&back, payload, "{kind}: payload must round trip");
        // decoded value re-encodes to the same bytes (bit-exact floats)
        let reencoded = match *kind {
            "traces" => codec::encode_trace(&codec::decode_trace(&back).unwrap()),
            "sensitivity" => {
                codec::encode_sensitivity(&codec::decode_sensitivity(&back).unwrap())
            }
            "study" => codec::encode_study(&codec::decode_study(&back).unwrap()),
            "train_fp" => ModelState::from_bytes(&back, "cnn_mnist").unwrap().to_bytes(),
            other => unreachable!("{other}"),
        };
        assert_eq!(&reencoded, payload, "{kind}: decode/encode must be bit-exact");

        // version bump invalidates
        assert!(cache.load(kind, *schema + 1, &key).is_none(), "{kind}: schema bump");

        // truncation falls back to a miss at several cut points
        let full = std::fs::read(&path).unwrap();
        for frac in [0usize, 1, 2] {
            let cut = full.len() * frac / 3;
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(cache.load(kind, *schema, &key).is_none(), "{kind}: cut {cut}");
        }
        std::fs::write(&path, &full).unwrap();
        assert!(cache.load(kind, *schema, &key).is_some(), "{kind}: restored");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Decoded study values survive the metrics/correlations Option structure.
#[test]
fn study_decode_preserves_structure() {
    let s = sample_study();
    let back = codec::decode_study(&codec::encode_study(&s)).unwrap();
    assert_eq!(back.model, s.model);
    assert_eq!(back.outcomes.len(), 2);
    assert_eq!(back.outcomes[0].cfg, s.outcomes[0].cfg);
    assert_eq!(back.outcomes[0].metrics, s.outcomes[0].metrics);
    assert_eq!(back.correlations, s.correlations);
    assert_eq!(back.sens.inputs.bn_gamma, s.sens.inputs.bn_gamma);
    assert_eq!(back.failures, s.failures);
}

/// End-to-end: a cold study computes each stage once, an in-process
/// rerun computes nothing, and a fresh pipeline over the same cache (the
/// cross-process case) reproduces the cold result bit-for-bit without
/// recomputing. Runs on every checkout: PJRT when artifacts are present,
/// the native backend otherwise.
#[test]
fn run_study_cold_vs_warm_bit_identity_and_stage_counts() {
    let rt = common::runtime();
    let dir = tmp_dir("coldwarm");
    let mut opt = StudyOptions {
        n_configs: 4,
        fp_epochs: 2,
        qat_epochs: 1,
        eval_n: 64,
        seed: 5,
        ..Default::default()
    };
    opt.trace.max_iters = 30;

    // cold: every stage computes exactly once
    let pipe = Pipeline::new(&dir).expect("pipeline");
    let cold = run_study(&rt, &pipe, "cnn_mnist", &opt).expect("cold study");
    let c = pipe.counters();
    assert_eq!(c.train_fp_computed(), 1, "one FP training");
    assert_eq!(c.sensitivity_computed(), 1, "one sensitivity gather");
    assert_eq!(c.study_computed(), 1, "one study sweep");

    // warm, same pipeline: pure cache read, counters unchanged
    let warm = run_study(&rt, &pipe, "cnn_mnist", &opt).expect("warm study");
    assert_eq!(c.train_fp_computed(), 1, "warm rerun must not retrain");
    assert_eq!(c.sensitivity_computed(), 1);
    assert_eq!(c.study_computed(), 1);
    assert_eq!(
        codec::encode_study(&warm),
        codec::encode_study(&cold),
        "warm study must be bit-identical to cold"
    );

    // fresh pipeline over the same results root = a second process
    let pipe2 = Pipeline::new(&dir).expect("pipeline 2");
    let cross = run_study(&rt, &pipe2, "cnn_mnist", &opt).expect("cross-process study");
    let c2 = pipe2.counters();
    assert_eq!(
        (c2.train_fp_computed(), c2.sensitivity_computed(), c2.study_computed()),
        (0, 0, 0),
        "second process must compute nothing"
    );
    assert_eq!(codec::encode_study(&cross), codec::encode_study(&cold));

    // the study cache is jobs-agnostic: a warm hit at jobs=4 returns the
    // jobs=1 result (which the determinism contract guarantees identical)
    opt.jobs = 4;
    let warm4 = run_study(&rt, &pipe2, "cnn_mnist", &opt).expect("warm study jobs=4");
    assert_eq!(codec::encode_study(&warm4), codec::encode_study(&cold));

    std::fs::remove_dir_all(&dir).ok();
}
