//! Search-service integration tests: the fail-closed request corpus,
//! the index-pure sampling pins, the sharding determinism contract
//! (streamed accumulator == one-shot sweep == quadratic reference at
//! every shard count and jobs setting), end-to-end `ServiceCore`
//! execution against the real pipeline, and real-TCP concurrent clients
//! sharing one lease-coordinated cold study.
//!
//! Everything under a response's `result` key is part of the
//! determinism contract; only the `metrics` trailer (wall-clock) may
//! vary. Tests therefore compare terminal lines up to `,"metrics":`.

mod common;

use std::sync::Arc;

use fitq::coordinator::service::{
    bind, fetch_stats, parse_request, plan_shards, query, sample_indices_into, sampled_config,
    serve_on, ErrorKind, ServiceConfig, ServiceCore, ServiceWorker,
};
use fitq::coordinator::{
    pareto_front_scores, pareto_front_scores_naive, FrontPoint, ParetoAccumulator,
};
use fitq::metrics::{FitTable, SensitivityInputs};
use fitq::quant::{BitConfig, PRECISIONS};
use fitq::runtime::Json;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fitq_svc_{tag}_{}", std::process::id()))
}

/// The request-order-invariant prefix of a terminal `done` line: every
/// byte of `result` but none of the wall-clock metrics.
fn invariant(line: &str) -> &str {
    let cut = line.rfind(",\"metrics\":").expect("done line has a metrics trailer");
    &line[..cut]
}

fn kind_of(line: &str) -> ErrorKind {
    parse_request(line).unwrap_err().kind
}

// ---------------------------------------------------------------------------
// Protocol: fail-closed parse corpus

#[test]
fn request_corpus_fails_closed_with_typed_kinds() {
    let study = r#""study":{"model":"cnn_mnist","fp_epochs":1,"seed":0}"#;
    // Every line below must draw exactly the kind on the right — a new
    // decoder that silently defaults or coerces any of them is a
    // protocol regression, not a convenience.
    let corpus: Vec<(String, ErrorKind)> = vec![
        ("".into(), ErrorKind::Parse),
        ("not json".into(), ErrorKind::Parse),
        ("[1,2]".into(), ErrorKind::Parse),
        ("\"ping\"".into(), ErrorKind::Parse),
        (r#"{"method":"ping""#.into(), ErrorKind::Parse),
        (r#"{"method":"frobnicate"}"#.into(), ErrorKind::Method),
        (r#"{"method":"PING"}"#.into(), ErrorKind::Method),
        (r#"{}"#.into(), ErrorKind::Schema), // no method
        (r#"{"method":7}"#.into(), ErrorKind::Schema),
        (r#"{"method":"ping","extra":1}"#.into(), ErrorKind::Schema),
        (r#"{"method":"stats","study":{}}"#.into(), ErrorKind::Schema),
        (r#"{"method":"score"}"#.into(), ErrorKind::Schema), // no study
        (format!(r#"{{"method":"score",{study}}}"#), ErrorKind::Schema), // no configs
        (r#"{"method":"score","study":[],"configs":[]}"#.into(), ErrorKind::Schema),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"bogus":1},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"","fp_epochs":1,"seed":0},"configs":[]}"#.into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":-1},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0.5},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":1e300},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        // strict trace overrides
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"trace":{"nope":1}},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"trace":{"batch":0}},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"trace":{"tol":-0.5}},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"trace":{"min_iters":0}},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        (
            r#"{"method":"score","study":{"model":"m","fp_epochs":1,"seed":0,"trace":{"min_iters":8,"max_iters":4}},"configs":[]}"#
                .into(),
            ErrorKind::Schema,
        ),
        // configs shape
        (
            format!(r#"{{"method":"score",{study},"configs":[17]}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"score",{study},"configs":[{{"w":[8],"a":[3],"x":1}}]}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"score",{study},"configs":[{{"w":[8]}}]}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"score",{study},"configs":[{{"w":[0],"a":[]}}]}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"score",{study},"configs":[{{"w":[2.5],"a":[]}}]}}"#),
            ErrorKind::Schema,
        ),
        // search: mode interlock
        (format!(r#"{{"method":"search",{study}}}"#), ErrorKind::Schema),
        (
            format!(r#"{{"method":"search",{study},"mode":"anneal","samples":1}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"random"}}"#),
            ErrorKind::Schema, // no samples
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"random","samples":0}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(
                r#"{{"method":"search",{study},"mode":"random","samples":10,"budget_bits":1}}"#
            ),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"greedy","budget_bits":1,"samples":2}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"greedy","budget_bits":1,"shards":2}}"#),
            ErrorKind::Schema,
        ),
        (format!(r#"{{"method":"search",{study},"mode":"greedy"}}"#), ErrorKind::Schema),
        (
            format!(
                r#"{{"method":"search",{study},"mode":"exact","budget_bits":1,"budget_ratio":0.5}}"#
            ),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"exact","budget_ratio":0}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"exact","budget_ratio":"x"}}"#),
            ErrorKind::Schema,
        ),
        // shards / stream
        (
            format!(r#"{{"method":"search",{study},"mode":"random","samples":1,"shards":0}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"search",{study},"mode":"random","samples":1,"stream":1}}"#),
            ErrorKind::Schema,
        ),
        (
            format!(r#"{{"method":"pareto",{study},"configs":[],"budget_bits":1}}"#),
            ErrorKind::Schema,
        ),
    ];
    for (line, want) in &corpus {
        assert_eq!(kind_of(line), *want, "corpus line: {line}");
    }

    // The accepted language, for contrast: every variant parses.
    for line in [
        r#"{"method":"ping"}"#.to_string(),
        r#"{"method":"stats"}"#.to_string(),
        format!(r#"{{"method":"score",{study},"configs":[{{"w":[8,4],"a":[3]}}]}}"#),
        format!(
            r#"{{"method":"search",{study},"mode":"random","samples":10,"seed":3,"shards":4,"stream":true}}"#
        ),
        format!(r#"{{"method":"search",{study},"mode":"greedy","budget_ratio":0.25}}"#),
        format!(r#"{{"method":"search",{study},"mode":"exact","budget_bits":50000}}"#),
        format!(r#"{{"method":"pareto",{study},"configs":[],"shards":2,"stream":false}}"#),
    ] {
        parse_request(&line).unwrap_or_else(|e| panic!("should parse: {line}: {e}"));
    }
}

// ---------------------------------------------------------------------------
// Sampling: cross-implementation pins + purity

/// Pins generated by an independent reimplementation (exact-integer
/// splitmix64 + PCG-XSH-RR 64/32) of `derive_seed` and `Pcg32` — if the
/// Rust stream ever drifts, served search results silently change, so
/// the draw itself is protocol surface.
#[test]
fn sample_stream_matches_reference_pins() {
    let mut idx = Vec::new();
    let pins: [(u64, &[u8]); 4] = [
        (0, &[2, 0, 3, 0, 1, 3]),
        (1, &[2, 2, 1, 2, 3, 1]),
        (2, &[1, 0, 1, 1, 3, 2]),
        (3, &[0, 0, 2, 0, 3, 3]),
    ];
    for (index, want) in pins {
        sample_indices_into(6, 4, 3, index, &mut idx);
        assert_eq!(idx, want, "seed=3 index={index}");
    }
    sample_indices_into(5, 4, 0, 0, &mut idx);
    assert_eq!(idx, [3, 1, 3, 2, 0], "seed=0 index=0");
}

fn synthetic_table() -> FitTable {
    // Hand-picked so different precision choices produce well-spread
    // fits and sizes (3 weight blocks of very different size, 2 act
    // blocks) — enough structure for non-trivial fronts.
    let inputs = SensitivityInputs {
        w_traces: vec![40.0, 2.5, 0.125],
        a_traces: vec![9.0, 0.75],
        w_lo: vec![-1.0, -0.5, -0.25],
        w_hi: vec![1.0, 0.5, 0.25],
        a_lo: vec![0.0, 0.0],
        a_hi: vec![6.0, 3.0],
        bn_gamma: vec![Some(1.0), Some(0.5), None],
    };
    FitTable::new(&inputs, &[4096, 512, 64], 37, &PRECISIONS)
}

#[test]
fn sampled_config_expands_indices_through_the_precision_set() {
    let table = synthetic_table();
    let n = table.n_weight_blocks() + table.n_act_blocks();
    let mut idx = Vec::new();
    for index in [0u64, 1, 999, 1 << 33] {
        sample_indices_into(n, table.precisions().len(), 42, index, &mut idx);
        let cfg = sampled_config(&table, 42, index);
        let expand: Vec<u32> =
            idx.iter().map(|&i| table.precisions()[i as usize]).collect();
        assert_eq!(cfg.bits_w, expand[..table.n_weight_blocks()]);
        assert_eq!(cfg.bits_a, expand[table.n_weight_blocks()..]);
        // and the config scores identically through both paths
        let (fit, size) = table.score_size_indices(&idx);
        let (fit2, size2) = table.score_size(&table.pack(&cfg));
        assert_eq!(fit.to_bits(), fit2.to_bits(), "index path == pack path");
        assert_eq!(size, size2);
    }
}

// ---------------------------------------------------------------------------
// Sharding determinism: accumulator == sweep == quadratic reference

/// The exact shard fold `run_search_random` performs, run here serially
/// over a synthetic table at many shard counts: every split must
/// reproduce the one-shot sweep bit-for-bit, and the sweep must agree
/// with the O(n²) dominance-scan ground truth (the regression pin for
/// the sort-then-sweep implementation).
#[test]
fn sharded_sampled_search_is_bit_identical_to_serial() {
    let table = synthetic_table();
    let n_blocks = table.n_weight_blocks() + table.n_act_blocks();
    let n_prec = table.precisions().len();
    let (samples, seed) = (3000u64, 11u64);

    // serial reference: score every sample index in order
    let mut idx = Vec::new();
    let mut scores = Vec::with_capacity(samples as usize);
    for k in 0..samples {
        sample_indices_into(n_blocks, n_prec, seed, k, &mut idx);
        scores.push(table.score_size_indices(&idx));
    }
    let want = pareto_front_scores(&scores);
    assert_eq!(want, pareto_front_scores_naive(&scores), "sweep == quadratic reference");
    assert!(!want.is_empty());

    let as_points = |ix: &[usize]| -> Vec<FrontPoint> {
        ix.iter()
            .map(|&i| FrontPoint { index: i, fit: scores[i].0, size_bits: scores[i].1 })
            .collect()
    };
    let want_points = as_points(&want);

    for shards in [1usize, 2, 3, 7, 16, 61, 256] {
        let plan = plan_shards(samples, Some(shards), 65_536);
        // fold per-shard fronts in reverse completion order — the worst
        // case for an order-sensitive merge
        let mut acc = ParetoAccumulator::new();
        for &(lo, hi) in plan.iter().rev() {
            let mut local = ParetoAccumulator::new();
            for k in lo..hi {
                sample_indices_into(n_blocks, n_prec, seed, k, &mut idx);
                let (fit, size_bits) = table.score_size_indices(&idx);
                local.push(FrontPoint { index: k as usize, fit, size_bits });
            }
            acc.absorb_front(local.front());
        }
        let got = acc.front();
        assert_eq!(got.len(), want_points.len(), "{shards} shards");
        for (g, w) in got.iter().zip(&want_points) {
            assert_eq!(g.index, w.index, "{shards} shards");
            assert_eq!(g.fit.to_bits(), w.fit.to_bits(), "{shards} shards: fit bits");
            assert_eq!(g.size_bits, w.size_bits, "{shards} shards");
        }
        // idempotent: re-absorbing every raw score changes nothing
        let snapshot = acc.front().to_vec();
        acc.absorb_scores(0, &scores);
        assert_eq!(acc.front(), &snapshot[..], "{shards} shards: idempotent re-absorb");
    }
}

/// `score_batch_into` is the service's explicit-config scorer: the
/// buffer is reused across calls (shrinks included) and the parallel
/// panel schedule never changes a single bit of the output.
#[test]
fn score_batch_into_reuses_buffer_and_is_jobs_invariant() {
    let table = synthetic_table();
    let configs: Vec<_> = (0..500u64).map(|i| table.pack(&sampled_config(&table, 9, i))).collect();
    let mut out = vec![(f64::NAN, u64::MAX); 3]; // stale contents must be cleared
    table.score_batch_into(&configs, 1, &mut out);
    assert_eq!(out.len(), configs.len());
    let serial = out.clone();
    for jobs in [0usize, 2, 4] {
        table.score_batch_into(&configs, jobs, &mut out);
        assert_eq!(out.len(), serial.len());
        for (a, b) in out.iter().zip(&serial) {
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "jobs={jobs}");
            assert_eq!(a.1, b.1, "jobs={jobs}");
        }
    }
    // shrinking reuse: a smaller batch must not leave stale tail entries
    table.score_batch_into(&configs[..7], 4, &mut out);
    assert_eq!(out.len(), 7);
    assert_eq!(out[..7], serial[..7]);
}

// ---------------------------------------------------------------------------
// ServiceCore end to end (real pipeline, cheap study)

/// A study spec kept deliberately tiny: one FP epoch, two fixed trace
/// iterations at batch 8, so the cold path trains once in seconds and
/// every test below shares the artifacts within its own results root.
fn study_json(seed: u64, max_iters: u64) -> String {
    format!(
        r#"{{"model":"cnn_mnist","fp_epochs":1,"seed":{seed},"trace":{{"batch":8,"min_iters":2,"max_iters":{max_iters}}}}}"#
    )
}

fn exec(core: &ServiceCore, w: &ServiceWorker, line: &str) -> Vec<String> {
    let req = parse_request(line).unwrap_or_else(|e| panic!("request parses: {line}: {e}"));
    let mut out: Vec<String> = Vec::new();
    core.execute(w, &req, &mut |l: &str| {
        out.push(l.to_string());
        Ok(())
    })
    .expect("in-process emit never fails transport");
    out
}

fn residency_of(done: &str) -> String {
    let j = Json::parse(done).expect("done line is JSON");
    j.field("metrics").unwrap().str_field("table").unwrap().to_string()
}

#[test]
fn service_core_serves_deterministic_sharded_results() {
    let dir = tmp_dir("core");
    std::fs::remove_dir_all(&dir).ok();
    let spec = common::runtime().spec();
    let cfg = ServiceConfig { jobs: 1, table_capacity: 1, shard_target: 512 };
    let core = ServiceCore::new(spec.clone(), &dir, cfg);
    let w = core.worker().expect("worker");
    let study = study_json(0, 2);

    // --- cold study: the first request trains + traces, later ones hit
    let search =
        |extra: &str| format!(r#"{{"method":"search","study":{study},"mode":"random","samples":600,"seed":7{extra}}}"#);
    let cold = exec(&core, &w, &search(""));
    assert_eq!(cold.len(), 1, "unstreamed search emits exactly one event");
    assert_eq!(residency_of(&cold[0]), "cold+compute");
    assert_eq!(core.counters().sensitivity_computed(), 1);
    let reference = invariant(&cold[0]).to_string();
    assert!(reference.contains("\"method\":\"search\""));
    assert!(reference.contains("\"samples\":600"));

    // --- shard-count invariance on the warm table
    for shards in [1usize, 3, 7, 600] {
        let line = exec(&core, &w, &search(&format!(r#","shards":{shards}"#)));
        assert_eq!(invariant(&line[0]), reference, "shards={shards}");
        assert_eq!(residency_of(&line[0]), "warm");
    }

    // --- jobs invariance: a second core (jobs=4) over the same results
    // root resolves cold from the published artifact, never retraining
    let core4 =
        ServiceCore::new(spec, &dir, ServiceConfig { jobs: 4, table_capacity: 1, shard_target: 64 });
    let w4 = core4.worker().expect("worker");
    let line = exec(&core4, &w4, &search(r#","shards":9"#));
    assert_eq!(invariant(&line[0]), reference, "jobs=4, shards=9");
    assert_eq!(residency_of(&line[0]), "cold+cache");
    assert_eq!(core4.counters().sensitivity_computed(), 0, "artifact reused, not recomputed");

    // --- streaming: monotone front progress, terminal line unchanged
    let streamed = exec(&core, &w, &search(r#","shards":5,"stream":true"#));
    assert_eq!(streamed.len(), 6, "5 front events + 1 done");
    for (i, line) in streamed[..5].iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.str_field("event").unwrap(), "front");
        assert_eq!(j.usize_field("shards_done").unwrap(), i + 1, "serial core: in-order");
        assert_eq!(j.usize_field("shards").unwrap(), 5);
    }
    assert_eq!(invariant(&streamed[5]), reference);
    // the last front event already carries the final front
    let last_front = Json::parse(&streamed[4]).unwrap();
    let done = Json::parse(&streamed[5]).unwrap();
    assert_eq!(
        last_front.field("front").unwrap(),
        done.field("result").unwrap().field("front").unwrap(),
        "front after the last shard == terminal front"
    );

    // --- explicit configs: score + pareto
    let rt = common::runtime();
    let mm = rt.model("cnn_mnist").unwrap();
    let (lw, la) = (mm.n_weight_blocks(), mm.n_act_blocks());
    let uni = |bits: u32| {
        let cfg = BitConfig::uniform(lw, la, bits);
        let join = |v: &[u32]| v.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",");
        format!(r#"{{"w":[{}],"a":[{}]}}"#, join(&cfg.bits_w), join(&cfg.bits_a))
    };
    let score_line = exec(
        &core,
        &w,
        &format!(r#"{{"method":"score","study":{study},"configs":[{},{}]}}"#, uni(8), uni(3)),
    );
    let j = Json::parse(&score_line[0]).unwrap();
    let scores = j.field("result").unwrap().arr_field("scores").unwrap().to_vec();
    assert_eq!(scores.len(), 2);
    let (fit8, size8) = (
        scores[0].as_arr().unwrap()[0].as_f64().unwrap(),
        scores[0].as_arr().unwrap()[1].as_f64().unwrap(),
    );
    let (fit3, size3) = (
        scores[1].as_arr().unwrap()[0].as_f64().unwrap(),
        scores[1].as_arr().unwrap()[1].as_f64().unwrap(),
    );
    assert!(fit8 <= fit3, "more bits, less noise: {fit8} vs {fit3}");
    assert!(size8 > size3, "more bits, more storage");

    let pareto = |shards: usize| {
        exec(
            &core,
            &w,
            &format!(
                r#"{{"method":"pareto","study":{study},"configs":[{},{},{},{}],"shards":{shards}}}"#,
                uni(8),
                uni(6),
                uni(4),
                uni(3)
            ),
        )
    };
    let p1 = pareto(1);
    let front = Json::parse(&p1[0]).unwrap();
    let front = front.field("result").unwrap().arr_field("front").unwrap().to_vec();
    assert!(!front.is_empty());
    for p in &front {
        let cfg = p.field("config").unwrap();
        assert_eq!(cfg.usize_array("w").unwrap().len(), lw);
        assert_eq!(cfg.usize_array("a").unwrap().len(), la);
    }
    assert_eq!(invariant(&p1[0]), invariant(&pareto(3)[0]), "pareto shard invariance");

    // --- config validation is a typed error, not a worker panic
    let bad = exec(
        &core,
        &w,
        &format!(r#"{{"method":"score","study":{study},"configs":[{{"w":[8],"a":[3]}}]}}"#),
    );
    let j = Json::parse(&bad[0]).unwrap();
    assert_eq!(j.str_field("event").unwrap(), "error");
    assert_eq!(j.str_field("kind").unwrap(), "config");
    let bad = exec(&core, &w, &format!(r#"{{"method":"score","study":{study},"configs":[{}]}}"#, uni(5)));
    assert_eq!(Json::parse(&bad[0]).unwrap().str_field("kind").unwrap(), "config");

    // --- unknown model is a study error
    let bad = exec(
        &core,
        &w,
        r#"{"method":"score","study":{"model":"nope","fp_epochs":1,"seed":0},"configs":[]}"#,
    );
    assert_eq!(Json::parse(&bad[0]).unwrap().str_field("kind").unwrap(), "study");

    // --- greedy/exact allocation through the service
    let g = exec(
        &core,
        &w,
        &format!(r#"{{"method":"search","study":{study},"mode":"greedy","budget_ratio":0.5}}"#),
    );
    let j = Json::parse(&g[0]).unwrap();
    let r = j.field("result").unwrap();
    assert_eq!(r.str_field("mode").unwrap(), "greedy");
    let budget = r.field("budget_bits").unwrap().as_f64().unwrap();
    let size = r.field("size_bits").unwrap().as_f64().unwrap();
    assert!(size <= budget, "allocation respects the budget");
    assert!(r.field("fit").unwrap().as_f64().unwrap().is_finite());
    assert_eq!(r.field("config").unwrap().usize_array("w").unwrap().len(), lw);

    // an infeasible budget is a typed budget error — and the worker
    // survives to answer the next request
    let e = exec(
        &core,
        &w,
        &format!(r#"{{"method":"search","study":{study},"mode":"exact","budget_bits":1}}"#),
    );
    assert_eq!(Json::parse(&e[0]).unwrap().str_field("kind").unwrap(), "budget");
    let pong = exec(&core, &w, r#"{"method":"ping"}"#);
    assert!(pong[0].contains("\"method\":\"ping\""));

    // --- LRU eviction at capacity 1: a second study (different trace
    // options => different stage digest, same training artifact) evicts
    // the first; re-requesting the first rebuilds from cache
    let study_b = study_json(0, 3);
    let b = exec(
        &core,
        &w,
        &format!(r#"{{"method":"search","study":{study_b},"mode":"random","samples":50,"seed":1}}"#),
    );
    assert_eq!(residency_of(&b[0]), "cold+compute", "new digest computes new traces");
    assert_eq!(core.counters().sensitivity_computed(), 2);
    let a_again = exec(&core, &w, &search(""));
    assert_eq!(residency_of(&a_again[0]), "cold+cache", "evicted table rebuilds from artifact");
    assert_eq!(invariant(&a_again[0]), reference, "rebuilt table scores identically");
    assert_eq!(core.counters().sensitivity_computed(), 2, "no recompute after eviction");
    let a_warm = exec(&core, &w, &search(""));
    assert_eq!(residency_of(&a_warm[0]), "warm");

    // --- stats reflect all of the above
    let stats = exec(&core, &w, r#"{"method":"stats"}"#);
    let j = Json::parse(&stats[0]).unwrap();
    let r = j.field("result").unwrap();
    assert!(r.field("requests").unwrap().as_f64().unwrap() >= 10.0);
    assert!(r.field("errors").unwrap().as_f64().unwrap() >= 3.0);
    assert!(r.field("table_hits").unwrap().as_f64().unwrap() >= 4.0);
    assert!(r.field("table_misses").unwrap().as_f64().unwrap() >= 3.0);
    assert_eq!(r.arr_field("tables").unwrap().len(), 1, "capacity-1 LRU holds one table");
    assert_eq!(
        r.field("stages").unwrap().field("sensitivity_computed").unwrap().as_f64().unwrap(),
        2.0
    );

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Real TCP: concurrent clients, one cold study, exactly-once compute

#[test]
fn tcp_concurrent_clients_get_identical_results_and_share_one_compute() {
    let dir = tmp_dir("tcp");
    std::fs::remove_dir_all(&dir).ok();
    let spec = common::runtime().spec();
    let core = Arc::new(ServiceCore::new(
        spec,
        &dir,
        ServiceConfig { jobs: 2, table_capacity: 4, shard_target: 128 },
    ));
    let listener = bind("127.0.0.1", 0).expect("ephemeral bind");
    let addr = listener.local_addr().unwrap().to_string();
    {
        let core = core.clone();
        std::thread::spawn(move || serve_on(core, listener));
    }

    let study = study_json(3, 2);
    let req = format!(
        r#"{{"method":"search","study":{study},"mode":"random","samples":700,"seed":5,"shards":4,"stream":true}}"#
    );
    let clients: Vec<_> = (0..3)
        .map(|_| {
            let (addr, req) = (addr.clone(), req.clone());
            std::thread::spawn(move || {
                let mut out: Vec<u8> = Vec::new();
                let any_error = query(&addr, &[req], &mut out).expect("query");
                assert!(!any_error, "search must succeed");
                String::from_utf8(out).expect("utf8 response")
            })
        })
        .collect();
    let outputs: Vec<String> = clients.into_iter().map(|c| c.join().expect("client")).collect();
    let dones: Vec<&str> =
        outputs.iter().map(|o| o.lines().last().expect("terminal line")).collect();
    for d in &dones {
        assert!(d.contains("\"event\":\"done\""), "terminal is a done event: {d}");
    }
    assert_eq!(invariant(dones[0]), invariant(dones[1]), "clients agree bit-for-bit");
    assert_eq!(invariant(dones[0]), invariant(dones[2]), "clients agree bit-for-bit");
    // each client saw 4 front events before its done line
    for o in &outputs {
        assert_eq!(o.lines().filter(|l| l.contains("\"event\":\"front\"")).count(), 4);
    }
    // three concurrent cold requests, one lease winner, one compute
    assert_eq!(core.counters().sensitivity_computed(), 1, "exactly-once across connections");

    // a parse failure answers once and hangs up — nonzero-ish for the CLI
    let mut out: Vec<u8> = Vec::new();
    let any_error = query(&addr, &["this is not json".to_string()], &mut out).expect("query");
    assert!(any_error);
    let text = String::from_utf8(out).unwrap();
    assert!(text.contains("\"kind\":\"parse\""), "typed parse error: {text}");

    // a schema failure keeps the connection serving subsequent requests
    let mut out: Vec<u8> = Vec::new();
    let any_error = query(
        &addr,
        &[r#"{"method":"ping","extra":1}"#.to_string(), r#"{"method":"ping"}"#.to_string()],
        &mut out,
    )
    .expect("query");
    assert!(any_error, "first request errored");
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
    assert_eq!(lines.len(), 2);
    assert!(lines[0].contains("\"kind\":\"schema\""));
    assert!(lines[1].contains("\"event\":\"done\""), "connection survived the schema error");

    // the stats helper the CLI's --stats flag uses
    let stats = fetch_stats(&addr).expect("stats");
    let j = Json::parse(&stats).unwrap();
    assert_eq!(
        j.field("result")
            .unwrap()
            .field("stages")
            .unwrap()
            .field("sensitivity_computed")
            .unwrap()
            .as_f64()
            .unwrap(),
        1.0
    );

    std::fs::remove_dir_all(&dir).ok();
}
