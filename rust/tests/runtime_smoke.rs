//! Integration: compile and dispatch entry points through the runtime —
//! over PJRT artifacts when `make artifacts` has run, else through the
//! zero-setup native backend, so these exercise a real backend on every
//! checkout.

use fitq::runtime::{Arg, Runtime};

mod common;

fn runtime() -> Option<Runtime> {
    Some(common::runtime())
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("cnn_mnist", "init").unwrap();
    let p1 = exe.run(&[Arg::U32Scalar(7)]).unwrap();
    let p2 = exe.run(&[Arg::U32Scalar(7)]).unwrap();
    let p3 = exe.run(&[Arg::U32Scalar(8)]).unwrap();
    let n = rt.model("cnn_mnist").unwrap().n_params;
    assert_eq!(p1.f32("params").unwrap().len(), n);
    assert_eq!(p1.f32("params").unwrap(), p2.f32("params").unwrap());
    assert_ne!(p1.f32("params").unwrap(), p3.f32("params").unwrap());
}

#[test]
fn train_epoch_runs_and_loss_is_finite() {
    let Some(rt) = runtime() else { return };
    let model = rt.model("cnn_mnist").unwrap().clone();
    let init = rt.load("cnn_mnist", "init").unwrap();
    let epoch = rt.load("cnn_mnist", "train_epoch").unwrap();

    let params = init.run(&[Arg::U32Scalar(0)]).unwrap().f32("params").unwrap().to_vec();
    let m = vec![0.0f32; model.n_params];
    let v = vec![0.0f32; model.n_params];
    let ds = fitq::data::SynthClass::synmnist(1);
    let (eb, _) = fitq::data::EpochBatch::generate(&ds, model.train_k, model.train_b, 0);

    let out = epoch
        .run(&[
            Arg::F32(&params),
            Arg::F32(&m),
            Arg::F32(&v),
            Arg::F32Scalar(0.0),
            Arg::F32(&eb.xs),
            Arg::I32(&eb.ys),
        ])
        .unwrap();
    let loss = out.scalar("loss").unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert_eq!(out.scalar("step").unwrap(), model.train_k as f32);
    // parameters moved
    assert_ne!(out.f32("params").unwrap(), params.as_slice());
}

#[test]
fn arg_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("cnn_mnist", "init").unwrap();
    assert!(exe.run(&[Arg::F32Scalar(1.0)]).is_err(), "dtype mismatch");
    assert!(exe.run(&[]).is_err(), "arity mismatch");
    let pr = rt.load("cnn_mnist", "param_ranges").unwrap();
    let too_short = vec![0.0f32; 3];
    assert!(pr.run(&[Arg::F32(&too_short)]).is_err(), "shape mismatch");
}

#[test]
fn ef_trace_outputs_per_block_values() {
    let Some(rt) = runtime() else { return };
    let model = rt.model("cnn_mnist").unwrap().clone();
    let init = rt.load("cnn_mnist", "init").unwrap();
    let ef = rt.load("cnn_mnist", "ef_trace_bs32").unwrap();
    let params = init.run(&[Arg::U32Scalar(3)]).unwrap().f32("params").unwrap().to_vec();

    let ds = fitq::data::SynthClass::synmnist(2);
    let sl = 16 * 16;
    let mut x = vec![0.0f32; 32 * sl];
    let mut y = vec![0i32; 32];
    for i in 0..32 {
        let mut yi = [0i32];
        fitq::data::Dataset::sample(&ds, fitq::data::Split::Test, i as u64, &mut x[i * sl..(i + 1) * sl], &mut yi);
        y[i] = yi[0];
    }
    let out = ef.run(&[Arg::F32(&params), Arg::F32(&x), Arg::I32(&y)]).unwrap();
    let w_tr = out.f32("w_tr").unwrap();
    let a_tr = out.f32("a_tr").unwrap();
    assert_eq!(w_tr.len(), model.n_weight_blocks());
    assert_eq!(a_tr.len(), model.n_act_blocks());
    assert!(w_tr.iter().all(|&t| t.is_finite() && t >= 0.0));
    assert!(a_tr.iter().all(|&t| t.is_finite() && t >= 0.0));
    assert!(w_tr.iter().sum::<f32>() > 0.0, "untrained model has nonzero grads");
}

#[test]
fn param_and_act_ranges_consistent_with_host_computation() {
    let Some(rt) = runtime() else { return };
    let model = rt.model("cnn_mnist").unwrap().clone();
    let init = rt.load("cnn_mnist", "init").unwrap();
    let params = init.run(&[Arg::U32Scalar(5)]).unwrap().f32("params").unwrap().to_vec();

    let pr = rt.load("cnn_mnist", "param_ranges").unwrap();
    let out = pr.run(&[Arg::F32(&params)]).unwrap();
    let lo = out.f32("lo").unwrap();
    let hi = out.f32("hi").unwrap();
    for (i, wb) in model.weight_blocks.iter().enumerate() {
        let slab = &params[wb.offset..wb.offset + wb.size];
        let (mn, mx) = fitq::tensor::min_max(slab).unwrap();
        assert!((lo[i] - mn).abs() < 1e-6, "block {i} lo");
        assert!((hi[i] - mx).abs() < 1e-6, "block {i} hi");
    }
}
