//! CLI smoke tests: bad inputs must fail fast with usage text, before any
//! runtime/artifact machinery is touched — so these run on a fresh
//! checkout with no artifacts.

use std::process::{Command, Output};

fn fitq(args: &[&str]) -> Output {
    // point the artifact root at nowhere so even an artifact-equipped
    // checkout stops at manifest load instead of actually training
    Command::new(env!("CARGO_BIN_EXE_fitq"))
        .env("FITQ_ARTIFACTS", "fitq-no-such-artifact-root")
        .env("FITQ_RESULTS", std::env::temp_dir().join("fitq_cli_smoke_results"))
        .args(args)
        .output()
        .expect("spawn fitq binary")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn no_args_prints_usage_and_succeeds() {
    let out = fitq(&[]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fitq <command>"), "{text}");
    assert!(text.contains("experiment"), "{text}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = fitq(&["frobnicate"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown command"), "{err}");
    assert!(err.contains("fitq <command>"), "usage text expected: {err}");
}

#[test]
fn bogus_experiment_fails_with_experiment_usage() {
    let out = fitq(&["experiment", "bogus"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown experiment"), "{err}");
    // the generated usage lists the registry
    for name in ["table1", "table2", "table3", "fig1", "fig2", "fig4", "fig5", "fig9"] {
        assert!(err.contains(name), "usage must list {name}: {err}");
    }
}

#[test]
fn experiment_without_name_fails_with_usage() {
    let out = fitq(&["experiment"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("experiment needs a name"), "{err}");
    assert!(err.contains("table2"), "{err}");
}

#[test]
fn unknown_flag_fails_with_usage() {
    // --runs is a table1 flag, not a fig9 flag
    let out = fitq(&["experiment", "fig9", "--runs", "3"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown flag --runs"), "{err}");
    assert!(err.contains("usage: fitq experiment"), "{err}");
}

#[test]
fn bad_flag_value_fails_before_runtime() {
    let out = fitq(&["experiment", "table1", "--iters", "many"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("--iters must be an integer"), "{err}");
    // and a flag with a missing value is caught by the parser
    let out = fitq(&["experiment", "table1", "--iters"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("needs a value"), "{}", stderr(&out));
}

#[test]
fn global_flags_are_accepted_by_every_experiment() {
    // validation passes; on an artifact-less checkout the failure (if
    // any) must come from the missing manifest, not from flag handling
    for name in ["fig9", "fig5", "table1", "all"] {
        let out = fitq(&["experiment", name, "--seed", "1", "--jobs", "2"]);
        let err = stderr(&out);
        assert!(!err.contains("unknown flag"), "{name}: {err}");
        assert!(!err.contains("unknown experiment"), "{name}: {err}");
        if !out.status.success() {
            assert!(
                err.contains("manifest.json") || err.contains("artifacts"),
                "{name} must only fail on missing artifacts: {err}"
            );
        }
    }
}
