//! CLI smoke tests: bad inputs must fail fast with usage text, before any
//! runtime/artifact machinery is touched — so these run on a fresh
//! checkout with no artifacts.

use std::process::{Command, Output};

fn fitq(args: &[&str]) -> Output {
    fitq_env(args, &[])
}

fn fitq_env(args: &[&str], envs: &[(&str, &str)]) -> Output {
    // point the artifact root at nowhere so even an artifact-equipped
    // checkout stops at manifest load instead of actually training
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_fitq"));
    cmd.env("FITQ_ARTIFACTS", "fitq-no-such-artifact-root")
        .env("FITQ_RESULTS", std::env::temp_dir().join("fitq_cli_smoke_results"))
        .env_remove("FITQ_BACKEND")
        .env_remove("FITQ_FAULTS");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.args(args).output().expect("spawn fitq binary")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn no_args_prints_usage_and_succeeds() {
    let out = fitq(&[]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fitq <command>"), "{text}");
    assert!(text.contains("experiment"), "{text}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = fitq(&["frobnicate"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown command"), "{err}");
    assert!(err.contains("fitq <command>"), "usage text expected: {err}");
}

#[test]
fn bogus_experiment_fails_with_experiment_usage() {
    let out = fitq(&["experiment", "bogus"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown experiment"), "{err}");
    // the generated usage lists the registry
    for name in ["table1", "table2", "table3", "fig1", "fig2", "fig4", "fig5", "fig9"] {
        assert!(err.contains(name), "usage must list {name}: {err}");
    }
}

#[test]
fn experiment_without_name_fails_with_usage() {
    let out = fitq(&["experiment"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("experiment needs a name"), "{err}");
    assert!(err.contains("table2"), "{err}");
}

#[test]
fn unknown_flag_fails_with_usage() {
    // --runs is a table1 flag, not a fig9 flag
    let out = fitq(&["experiment", "fig9", "--runs", "3"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown flag --runs"), "{err}");
    assert!(err.contains("usage: fitq experiment"), "{err}");
}

#[test]
fn bad_flag_value_fails_before_runtime() {
    let out = fitq(&["experiment", "table1", "--iters", "many"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("--iters must be an integer"), "{err}");
    // and a flag with a missing value is caught by the parser
    let out = fitq(&["experiment", "table1", "--iters"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("needs a value"), "{}", stderr(&out));
}

#[test]
fn global_flags_are_accepted_by_every_experiment() {
    // validation passes; pinned to --backend pjrt (whose artifact root
    // points at nowhere) so the run stops at the runtime instead of
    // actually executing on the native backend
    for name in ["fig9", "fig5", "table1", "all"] {
        let out = fitq(&["experiment", name, "--seed", "1", "--jobs", "2", "--backend", "pjrt"]);
        let err = stderr(&out);
        assert!(!err.contains("unknown flag"), "{name}: {err}");
        assert!(!err.contains("unknown experiment"), "{name}: {err}");
        if !out.status.success() {
            assert!(
                err.contains("manifest.json") || err.contains("artifacts"),
                "{name} must only fail on missing artifacts: {err}"
            );
        }
    }
}

#[test]
fn pjrt_failure_names_the_native_escape_hatch() {
    // the actionable error: a PJRT bring-up failure (missing artifacts
    // here; the stubbed xla client on a hermetic build) must point at
    // `--backend native` and the artifact-root env var
    let out = fitq(&["train", "--backend", "pjrt"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("--backend native"), "{err}");
    assert!(err.contains("FITQ_ARTIFACTS"), "{err}");
}

#[test]
fn unknown_backend_fails_fast() {
    let out = fitq(&["info", "--backend", "tpu"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown backend"), "{err}");
    assert!(err.contains("native|pjrt") || err.contains("native"), "{err}");
}

#[test]
fn native_backend_needs_no_artifacts() {
    // `info` on the native backend succeeds on a bare checkout and lists
    // the study models (no training happens here — info only reads the
    // generated manifest)
    let out = fitq(&["info", "--backend", "native"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backend: native"), "{text}");
    for model in ["cnn_mnist", "cnn_mnist_bn", "cnn_cifar", "cnn_cifar_bn"] {
        assert!(text.contains(model), "info must list {model}: {text}");
    }
}

/// Path of a committed zoo manifest, valid from the test's cwd.
fn zoo(name: &str) -> String {
    format!("{}/../zoo/{name}.json", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn missing_zoo_manifest_fails_before_runtime_with_usage() {
    let out = fitq(&["train", "--model", "zoo/definitely-missing.json"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("zoo/definitely-missing.json"), "must name the path: {err}");
    assert!(err.contains("usage:"), "must carry the zoo usage line: {err}");
    assert!(err.contains("--model"), "{err}");
}

#[test]
fn malformed_zoo_manifest_fails_before_runtime() {
    let dir = std::env::temp_dir().join(format!("fitq_cli_zoo_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("broken.json");
    std::fs::write(&path, "{\"schema_version\": 1, \"name\": \"bro").unwrap();
    let out = fitq(&["traces", "--model", path.to_str().unwrap()]);
    std::fs::remove_dir_all(&dir).ok();
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("broken.json"), "must name the path: {err}");
    assert!(err.contains("JSON"), "must say why: {err}");
    assert!(err.contains("usage:"), "{err}");
}

#[test]
fn out_of_vocabulary_op_names_the_layer_and_field() {
    let bad = format!(
        "{}/tests/corpus/manifests/bad/unsupported-op__upsample2.json",
        env!("CARGO_MANIFEST_DIR")
    );
    let out = fitq(&["train", "--model", &bad]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("upsample2"), "must name the op: {err}");
    assert!(err.contains("up0"), "must name the layer: {err}");
    assert!(err.contains("unsupported-op__upsample2.json"), "must name the path: {err}");
}

#[test]
fn zoo_manifest_conflicts_with_pjrt_backend() {
    let out = fitq(&["train", "--model", &zoo("cnn_mnist"), "--backend", "pjrt"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("native backend only"), "{err}");
}

#[test]
fn train_runs_from_a_zoo_manifest() {
    let out = fitq(&["train", "--model", &zoo("cnn_mnist"), "--epochs", "1"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cnn_mnist: 1 epochs"), "{text}");
}

#[test]
fn cache_commands_run_on_an_empty_store() {
    let dir = std::env::temp_dir().join(format!("fitq_cli_cache_empty_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let d = dir.to_str().unwrap();
    let out = fitq(&["cache", "stats", "--results", d]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(String::from_utf8_lossy(&out.stdout).contains("leases: 0"), "{out:?}");
    let out = fitq(&["cache", "gc", "--results", d]);
    assert!(out.status.success(), "{}", stderr(&out));
    let out = fitq(&["cache", "verify", "--results", d]);
    assert!(out.status.success(), "{}", stderr(&out));
    let out = fitq(&["cache", "defrag", "--results", d]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown cache operation"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_verify_quarantines_corruption_and_exits_nonzero() {
    let dir = std::env::temp_dir().join(format!("fitq_cli_cache_bad_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache_dir = dir.join("cache");
    std::fs::create_dir_all(&cache_dir).unwrap();
    let name = format!("study_{:032x}.bin", 0xabc_u128);
    std::fs::write(cache_dir.join(&name), b"definitely not a cache entry").unwrap();

    let out = fitq(&["cache", "verify", "--results", dir.to_str().unwrap()]);
    assert!(!out.status.success(), "corrupt store must exit nonzero");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("quarantined"), "{text}");
    assert!(stderr(&out).contains("corrupt"), "{}", stderr(&out));
    assert!(cache_dir.join("quarantine").join(&name).exists(), "entry must move, not vanish");
    assert!(!cache_dir.join(&name).exists());

    // with the corruption quarantined, a second verify is clean
    let out = fitq(&["cache", "verify", "--results", dir.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_fault_spec_fails_fast() {
    // a typo'd $FITQ_FAULTS must abort the run, not silently run clean
    let out = fitq_env(&["info", "--backend", "native"], &[("FITQ_FAULTS", "no.such.site")]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown fault site"), "{}", stderr(&out));
    let out = fitq_env(
        &["info", "--backend", "native"],
        &[("FITQ_FAULTS", "cache.store.short_write@zero")],
    );
    assert!(!out.status.success());
    assert!(stderr(&out).contains("bad fault hit count"), "{}", stderr(&out));
    // a well-formed spec arms and announces itself
    let out = fitq_env(
        &["info", "--backend", "native"],
        &[("FITQ_FAULTS", "cache.store.short_write")],
    );
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stderr(&out).contains("[fault] armed"), "{}", stderr(&out));
}

#[test]
fn search_flags_fail_fast_before_any_training() {
    let out = fitq(&["search", "--model", "cnn_mnist", "--samples", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--samples must be >= 1"), "{}", stderr(&out));

    let out = fitq(&["search", "--model", "cnn_mnist", "--shards", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--shards must be >= 1"), "{}", stderr(&out));

    // booleans are spelled --stream true|false in this parser
    let out = fitq(&["search", "--model", "cnn_mnist", "--stream", "maybe"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--stream must be true or false"), "{}", stderr(&out));
}

#[test]
fn serve_flags_fail_fast_before_binding() {
    let out = fitq(&["serve", "--port", "99999999"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--port must fit in 16 bits"), "{}", stderr(&out));

    let out = fitq(&["serve", "--port", "no"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--port must be an integer"), "{}", stderr(&out));

    // --stats against a dead address reports the connect failure
    let out = fitq(&["serve", "--stats", "127.0.0.1:9"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("connecting 127.0.0.1:9"), "{}", stderr(&out));
}

#[test]
fn query_needs_a_server_and_a_request() {
    let out = fitq(&["query"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("query needs --connect"), "{}", stderr(&out));

    // discard port (9) is reliably closed on loopback in the test env
    let out = fitq(&["query", "--connect", "127.0.0.1:9", r#"{"method":"ping"}"#]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("connecting 127.0.0.1:9"), "{}", stderr(&out));
}

#[test]
fn zoo_check_validates_the_committed_zoo() {
    let names = ["cnn_mnist", "cnn_mnist_bn", "cnn_cifar", "cnn_cifar_bn", "cnn_cifar_deep"];
    let paths: Vec<String> = names.iter().map(|n| zoo(n)).collect();
    let mut args = vec!["zoo-check"];
    args.extend(paths.iter().map(|p| p.as_str()));
    let out = fitq(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = String::from_utf8_lossy(&out.stdout);
    for n in names {
        assert!(text.contains(&format!("model {n}:")), "{text}");
    }
    assert_eq!(text.matches(": ok").count(), names.len(), "{text}");

    // and with no paths it explains itself
    let out = fitq(&["zoo-check"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("zoo-check zoo/*.json"), "{}", stderr(&out));
}
