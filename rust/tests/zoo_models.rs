//! The zoo bit-identity gate: every builtin model re-expressed as a
//! committed `zoo/*.json` manifest must be indistinguishable from the
//! hand-written builder — identical cache digests (via `hash_model` on
//! the compiled block layout), bit-identical init/train/trace outputs,
//! and byte-identical serialized study results at `jobs ∈ {1, 4}`. The
//! manifest-only `cnn_cifar_deep` then proves the zero-Rust-change
//! claim: a model no builder knows completes train → trace → study.

use std::path::PathBuf;

use fitq::coordinator::pipeline::codec::encode_study;
use fitq::coordinator::pipeline::stages::{study_key, train_fp_key};
use fitq::coordinator::{
    dataset_for, run_study, Estimator, ModelState, Pipeline, StudyOptions, StudyResult,
    TraceEngine, TraceOptions, Trainer,
};
use fitq::runtime::Runtime;

const BUILTINS: [&str; 4] = ["cnn_mnist", "cnn_mnist_bn", "cnn_cifar", "cnn_cifar_bn"];

fn zoo_path(name: &str) -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../zoo")).join(format!("{name}.json"))
}

/// Native runtime whose model came from the committed manifest (the zoo
/// plan shadows the builtin of the same name).
fn zoo_runtime(name: &str) -> Runtime {
    Runtime::native_with_zoo(1, vec![zoo_path(name)]).expect("zoo runtime")
}

fn hand_runtime() -> Runtime {
    Runtime::native_with_threads(1).expect("native runtime")
}

fn cold_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fitq_zoo_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Serialize a study with the single wall-clock field (the embedded
/// trace's ms/iter measurement) normalized away — everything else must
/// be byte-identical across equivalent runs.
fn study_bytes(mut s: StudyResult) -> Vec<u8> {
    s.sens.trace.iter_time_s = 0.0;
    encode_study(&s)
}

/// Init, two training epochs, and an EF trace are bit-identical between
/// the hand-built and manifest-built plan of every builtin — and their
/// pipeline cache digests coincide, so artifacts are interchangeable.
#[test]
fn manifest_builtins_are_bit_identical_to_hand_built() {
    for name in BUILTINS {
        let hand = hand_runtime();
        let zoo = zoo_runtime(name);

        // identical digests: hash_model sees the same block layout
        let k_hand = train_fp_key("native", hand.model(name).unwrap(), 2, 7);
        let k_zoo = train_fp_key("native", zoo.model(name).unwrap(), 2, 7);
        assert_eq!(k_hand, k_zoo, "{name}: manifest must share the builtin's cache digests");

        // bit-identical init
        let st_hand = ModelState::init(&hand, name, 3).unwrap();
        let st_zoo = ModelState::init(&zoo, name, 3).unwrap();
        assert_eq!(st_hand.params, st_zoo.params, "{name}: init diverged");

        // bit-identical training (losses and final parameters)
        let run = |rt: &Runtime| {
            let ds = dataset_for(rt, name, 7 ^ 0xda7a).unwrap();
            let mut trainer = Trainer::new(rt, ds.as_ref());
            let mut st = ModelState::init(rt, name, 3).unwrap();
            let losses = trainer.train(&mut st, 2).unwrap();
            (losses, st.params)
        };
        let (l_hand, p_hand) = run(&hand);
        let (l_zoo, p_zoo) = run(&zoo);
        assert_eq!(l_hand, l_zoo, "{name}: training losses diverged");
        assert_eq!(p_hand, p_zoo, "{name}: trained parameters diverged");

        // bit-identical EF trace over the trained parameters
        let trace = |rt: &Runtime, params: &[f32]| {
            let ds = dataset_for(rt, name, 7 ^ 0xda7a).unwrap();
            let engine = TraceEngine::new(rt, ds.as_ref());
            let opt =
                TraceOptions { batch: 32, tol: 0.01, min_iters: 4, max_iters: 12, seed: 5 };
            engine.run(name, params, Estimator::EmpiricalFisher, opt).unwrap()
        };
        let t_hand = trace(&hand, &p_hand);
        let t_zoo = trace(&zoo, &p_zoo);
        assert_eq!(t_hand.w_traces, t_zoo.w_traces, "{name}: weight traces diverged");
        assert_eq!(t_hand.a_traces, t_zoo.a_traces, "{name}: activation traces diverged");
        assert_eq!(t_hand.iterations, t_zoo.iterations, "{name}: iteration counts diverged");
    }
}

/// Full `run_study` is byte-identical (serialized through the cache
/// codec) between the hand-built plan at `jobs = 1` and the
/// manifest-built plan at `jobs ∈ {1, 4}` — cold pipelines each time, so
/// every run actually computes rather than reading a shared cache.
#[test]
fn manifest_builtins_study_byte_identical_across_jobs() {
    for name in BUILTINS {
        let mut opt = StudyOptions {
            n_configs: 3,
            fp_epochs: 2,
            qat_epochs: 1,
            eval_n: 128,
            seed: 11,
            ..Default::default()
        };
        opt.trace.max_iters = 24;

        let study = |rt: &Runtime, jobs: usize, tag: &str| {
            let dir = cold_dir(&format!("{name}_{tag}"));
            let pipe = Pipeline::new(&dir).expect("pipeline");
            let mut o = opt.clone();
            o.jobs = jobs;
            let s = run_study(rt, &pipe, name, &o).expect("study");
            std::fs::remove_dir_all(&dir).ok();
            study_bytes(s)
        };

        let hand = study(&hand_runtime(), 1, "hand_j1");
        let zoo_j1 = study(&zoo_runtime(name), 1, "zoo_j1");
        let zoo_j4 = study(&zoo_runtime(name), 4, "zoo_j4");
        assert_eq!(hand, zoo_j1, "{name}: hand vs manifest study bytes diverged");
        assert_eq!(zoo_j1, zoo_j4, "{name}: jobs=4 study bytes diverged");
    }
}

/// Key separation: a genuinely different manifest model must never
/// collide with a builtin's digests (the other half of the digest rule).
#[test]
fn new_manifest_model_gets_its_own_digests() {
    let rt = Runtime::native_with_zoo(
        1,
        vec![zoo_path("cnn_cifar_deep"), zoo_path("cnn_cifar_bn")],
    )
    .expect("zoo runtime");
    let deep = rt.model("cnn_cifar_deep").unwrap();
    let bn = rt.model("cnn_cifar_bn").unwrap();
    assert_ne!(
        train_fp_key("native", deep, 2, 7),
        train_fp_key("native", bn, 2, 7),
        "different architectures must separate in the train digest"
    );
    let opt = StudyOptions::default();
    assert_ne!(
        study_key("native", deep, &opt),
        study_key("native", bn, &opt),
        "…and in the study digest"
    );
}

/// The zero-Rust-change claim, end to end: the manifest-only
/// `cnn_cifar_deep` (4 conv stages — no builder knows it) trains,
/// traces, and completes a full study on the native backend.
#[test]
fn manifest_only_model_runs_train_trace_study() {
    let rt = Runtime::native_with_zoo(1, vec![zoo_path("cnn_cifar_deep")]).expect("zoo runtime");
    let mm = rt.model("cnn_cifar_deep").unwrap();
    assert_eq!(mm.n_weight_blocks(), 5, "4 convs + fc");
    assert_eq!(mm.n_act_blocks(), 4, "one activation block per conv");

    let mut opt = StudyOptions {
        n_configs: 2,
        fp_epochs: 1,
        qat_epochs: 1,
        eval_n: 128,
        seed: 13,
        ..Default::default()
    };
    opt.trace.max_iters = 16;
    let dir = cold_dir("deep_e2e");
    let pipe = Pipeline::new(&dir).expect("pipeline");
    let s = run_study(&rt, &pipe, "cnn_cifar_deep", &opt).expect("study on a manifest-only model");
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(s.model, "cnn_cifar_deep");
    assert_eq!(s.outcomes.len(), 2);
    assert!(s.fp_test_score.is_finite());
    assert_eq!(s.sens.inputs.w_traces.len(), 5);
    assert_eq!(s.sens.inputs.a_traces.len(), 4);
}
