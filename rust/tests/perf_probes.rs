//! §Perf measurement probes (PJRT probes run with --ignored; the native
//! profiler probe self-gates on FITQ_BENCH_SMOKE; results recorded in
//! EXPERIMENTS.md §Perf). These are measurements, not assertions — they
//! print numbers and only sanity-check direction.

use std::time::Instant;

use fitq::data::{EpochBatch, SynthClass};
use fitq::runtime::{Arg, Runtime};

mod common;

fn runtime() -> Option<Runtime> {
    common::artifact_root().map(|root| Runtime::new(root).expect("runtime"))
}

/// L2 §Perf: scanned K=10 epoch vs 10 single-step dispatches.
#[test]
#[ignore = "perf probe — run explicitly"]
fn scan_amortization() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_mnist";
    let mm = rt.model(model).unwrap().clone();
    let init = rt.load(model, "init").unwrap();
    let params = init.run(&[Arg::U32Scalar(0)]).unwrap().f32("params").unwrap().to_vec();
    let m = vec![0.0f32; mm.n_params];
    let v = m.clone();
    let ds = SynthClass::synmnist(1);
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    let (e1, _) = EpochBatch::generate(&ds, 1, mm.train_b, 0);

    let epoch = rt.load(model, "train_epoch").unwrap();
    let step = rt.load(model, "train_step").unwrap();
    let n = 20;

    // warmup
    for exe in [&epoch, &step] {
        let eb_ref = if std::rc::Rc::ptr_eq(exe, &epoch) { &eb } else { &e1 };
        exe.run(&[
            Arg::F32(&params),
            Arg::F32(&m),
            Arg::F32(&v),
            Arg::F32Scalar(0.0),
            Arg::F32(&eb_ref.xs),
            Arg::I32(&eb_ref.ys),
        ])
        .unwrap();
    }

    let t0 = Instant::now();
    for _ in 0..n {
        epoch
            .run(&[
                Arg::F32(&params),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap();
    }
    let scanned = t0.elapsed().as_secs_f64() / (n * mm.train_k) as f64;

    let t1 = Instant::now();
    for _ in 0..n {
        for _ in 0..mm.train_k {
            step.run(&[
                Arg::F32(&params),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32Scalar(0.0),
                Arg::F32(&e1.xs),
                Arg::I32(&e1.ys),
            ])
            .unwrap();
        }
    }
    let single = t1.elapsed().as_secs_f64() / (n * mm.train_k) as f64;

    println!(
        "scan_amortization: scanned K=10 {:.3} ms/step vs K=1 {:.3} ms/step ({:.2}x)",
        scanned * 1e3,
        single * 1e3,
        single / scanned
    );
    assert!(scanned < single, "scanned epochs must amortize dispatch cost");
}

/// L3 §Perf: input-literal reuse (copy_raw_from) vs rebuild-per-dispatch.
/// Uses the EF-trace executable whose inputs include the full parameter
/// vector — the dominant literal on the trace hot loop.
#[test]
#[ignore = "perf probe — run explicitly"]
fn literal_reuse() {
    let Some(rt) = runtime() else { return };
    let model = "cnn_l";
    let mm = rt.model(model).unwrap().clone();
    let init = rt.load(model, "init").unwrap();
    let params = init.run(&[Arg::U32Scalar(0)]).unwrap().f32("params").unwrap().to_vec();
    let ef = rt.load(model, "ef_trace_bs32").unwrap();
    let ds = SynthClass::new((16, 16, 3), 10, 1.5, 1);
    let (eb, _) = EpochBatch::generate(&ds, 1, 32, 0);
    let run = |n: usize| {
        let t0 = Instant::now();
        for _ in 0..n {
            ef.run(&[Arg::F32(&params), Arg::F32(&eb.xs), Arg::I32(&eb.ys)]).unwrap();
        }
        t0.elapsed().as_secs_f64() / n as f64
    };
    run(3); // warmup + allocate literals
    let reused = run(15);
    std::env::set_var("FITQ_NO_LITERAL_REUSE", "1");
    let rebuilt = run(15);
    std::env::remove_var("FITQ_NO_LITERAL_REUSE");
    println!(
        "literal_reuse: reused {:.2} ms vs rebuilt {:.2} ms per dispatch ({:.2}x)",
        reused * 1e3,
        rebuilt * 1e3,
        rebuilt / reused
    );
}

/// Native §Perf: the disarmed-profiler overhead contract. Tracing off
/// (the default) must cost one untaken branch per op — a traced-off
/// `train_epoch` built with `native::trace` record sites compiled in
/// stays within the noise band of the same epoch loop. Gated on
/// `FITQ_BENCH_SMOKE` like the Makefile's bench smoke (not `--ignored`:
/// it needs no PJRT artifacts, just an explicit opt-in to timing).
#[test]
fn disarmed_profiler_overhead_within_noise() {
    if std::env::var_os("FITQ_BENCH_SMOKE").is_none() {
        return; // timing probe: opt-in only, useless on a loaded CI host
    }
    assert!(
        std::env::var_os("FITQ_TRACE_OPS").is_none(),
        "probe measures the DISARMED path; unset FITQ_TRACE_OPS"
    );
    let rt = Runtime::native_with_threads(1).expect("native runtime");
    let model = "cnn_mnist";
    let mm = rt.model(model).unwrap().clone();
    let epoch = rt.load(model, "train_epoch").unwrap();
    let init = rt.load(model, "init").unwrap();
    let params = init.run(&[Arg::U32Scalar(0)]).unwrap().f32("params").unwrap().to_vec();
    let m = vec![0.0f32; mm.n_params];
    let v = m.clone();
    let ds = SynthClass::synmnist(1);
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    let run_epoch = || {
        epoch
            .run(&[
                Arg::F32(&params),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap();
    };
    // min-of-reps on both legs: minimum rejects scheduler noise, and the
    // two legs are the *same* binary path (profiler disarmed), so any
    // stable gap would be record-site overhead leaking into the off path
    let time_leg = |reps: usize| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            run_epoch();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    run_epoch(); // warmup (route-table resolve, allocations)
    let a = time_leg(5);
    let b = time_leg(5);
    let ratio = a.max(b) / a.min(b);

    // informational armed leg: same workload with the profiler recording
    // (a fresh runtime, since arming happens at backend creation)
    std::env::set_var("FITQ_TRACE_OPS", "1");
    let rt_on = Runtime::native_with_threads(1).expect("native runtime");
    std::env::remove_var("FITQ_TRACE_OPS");
    let epoch_on = rt_on.load(model, "train_epoch").unwrap();
    let mut armed = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        epoch_on
            .run(&[
                Arg::F32(&params),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap();
        armed = armed.min(t0.elapsed().as_secs_f64());
    }

    println!(
        "disarmed_profiler_overhead: leg A {:.3} ms, leg B {:.3} ms ({ratio:.3}x); \
         armed {:.3} ms for reference",
        a * 1e3,
        b * 1e3,
        armed * 1e3,
    );
    assert!(
        ratio < 1.25,
        "traced-off epochs must agree within the noise band: {a:.6}s vs {b:.6}s ({ratio:.3}x)"
    );
}
