//! The manifest golden corpus: every file under
//! `tests/corpus/manifests/bad/` must fail closed with the error kind
//! its filename declares (`<kind>__<description>.json`), and every file
//! under `good/` — plus every committed `zoo/*.json` — must round-trip
//! parse → serialize → parse identically and compile to the same spec.

use std::path::{Path, PathBuf};

use fitq::native::manifest::{load_str, ManifestError, ZooManifest};

fn corpus(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus/manifests").join(sub)
}

fn json_files(dir: &Path) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    files
}

/// The error-kind contract: `bad/<kind>__<desc>.json` fails with exactly
/// `<kind>`. A case that parses, or fails with a *different* kind, is a
/// validation hole — both directions matter.
#[test]
fn bad_corpus_fails_closed_with_the_named_error() {
    let files = json_files(&corpus("bad"));
    assert!(
        files.len() >= 12,
        "the negative corpus thinned out: {} cases left",
        files.len()
    );
    for path in files {
        let stem = path.file_stem().unwrap().to_str().unwrap();
        let expected = stem
            .split_once("__")
            .unwrap_or_else(|| panic!("{stem}: corpus files are named <kind>__<desc>.json"))
            .0;
        let text = std::fs::read_to_string(&path).unwrap();
        match load_str(&text) {
            Ok(_) => panic!("{stem}: expected a {expected:?} rejection, but it parsed"),
            Err(e) => assert_eq!(
                e.kind(),
                expected,
                "{stem}: wrong rejection class: {e}"
            ),
        }
    }
}

/// Every rejection's Display must carry enough context to act on — at
/// minimum it never collapses to an empty or kind-only string.
#[test]
fn bad_corpus_errors_are_descriptive() {
    for path in json_files(&corpus("bad")) {
        let text = std::fs::read_to_string(&path).unwrap();
        let e = load_str(&text).unwrap_err();
        let msg = e.to_string();
        assert!(
            msg.len() > e.kind().len() + 4,
            "{}: error message {msg:?} carries no detail",
            path.display()
        );
    }
}

fn assert_round_trips(path: &Path) {
    let text = std::fs::read_to_string(path).unwrap();
    let m = ZooManifest::parse(&text)
        .unwrap_or_else(|e| panic!("{}: should parse: {e}", path.display()));
    let spec = m
        .compile()
        .unwrap_or_else(|e| panic!("{}: should compile: {e}", path.display()));
    let re = ZooManifest::parse(&m.to_json())
        .unwrap_or_else(|e| panic!("{}: canonical form should re-parse: {e}", path.display()));
    assert_eq!(re, m, "{}: parse(to_json(m)) must equal m", path.display());
    assert_eq!(re.compile().unwrap(), spec, "{}: compile must agree too", path.display());
}

#[test]
fn good_corpus_round_trips_identically() {
    let files = json_files(&corpus("good"));
    assert!(files.len() >= 2, "good corpus is empty");
    for path in &files {
        assert_round_trips(path);
    }
}

/// The committed zoo is held to the same contract as the good corpus —
/// it *is* the production corpus.
#[test]
fn committed_zoo_round_trips_identically() {
    let zoo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../zoo");
    let files = json_files(&zoo);
    assert!(files.len() >= 5, "expected the 4 builtins + >=1 zoo-only model");
    for path in &files {
        assert_round_trips(path);
        // zoo files additionally declare the name they are stored under
        let text = std::fs::read_to_string(path).unwrap();
        let m = load_str(&text).unwrap();
        assert_eq!(
            Some(m.spec.name.as_str()),
            path.file_stem().and_then(|s| s.to_str()),
            "{}: zoo filename must match the declared model name",
            path.display()
        );
    }
}

/// `kind()` strings are a stable API (the corpus and CLI lean on them);
/// pin the full set.
#[test]
fn error_kinds_are_stable() {
    let kinds = [
        ManifestError::Json(String::new()).kind(),
        ManifestError::SchemaVersion(String::new()).kind(),
        ManifestError::UnknownField { context: String::new(), field: String::new() }.kind(),
        ManifestError::MissingField { context: String::new(), field: String::new() }.kind(),
        ManifestError::WrongType {
            context: String::new(),
            field: String::new(),
            expected: "",
        }
        .kind(),
        ManifestError::BadValue { context: String::new(), detail: String::new() }.kind(),
        ManifestError::DuplicateLayer { name: String::new() }.kind(),
        ManifestError::DanglingRef { context: String::new(), target: String::new() }.kind(),
        ManifestError::CyclicOrder { layer: String::new(), after: String::new() }.kind(),
        ManifestError::Structure { detail: String::new() }.kind(),
        ManifestError::UnsupportedOp { layer: String::new(), op: String::new() }.kind(),
        ManifestError::ShapeMismatch { context: String::new(), detail: String::new() }.kind(),
        ManifestError::QuantPlacement { layer: String::new(), detail: String::new() }.kind(),
    ];
    assert_eq!(
        kinds,
        [
            "json",
            "schema-version",
            "unknown-field",
            "missing-field",
            "wrong-type",
            "bad-value",
            "duplicate-layer",
            "dangling-ref",
            "cyclic-order",
            "structure",
            "unsupported-op",
            "shape-mismatch",
            "quant-placement",
        ]
    );
}
