//! Deterministic fault-injection drills over the artifact store, lease
//! layer and worker pool (DESIGN.md "Failure model").
//!
//! One scenario per registered injection site: arm the site, run the full
//! study pipeline against a stage-prewarmed store, and require the
//! contract — every fault degrades to a recompute, a wait-and-takeover,
//! or a typed error; never a crash, never wrong bytes. After the fault
//! clears, a recovery run over the same store must reproduce the
//! fault-free baseline bit-for-bit.
//!
//! The store is prewarmed with the baseline's *stage* artifacts (FP
//! checkpoint, sensitivity report) because trace wall-clock is part of
//! the cached sensitivity payload: sharing the expensive prefix is what
//! makes study bytes comparable across scenarios.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use fitq::coordinator::pipeline::codec::encode_study;
use fitq::coordinator::pipeline::fault::{self, site, FaultPlan};
use fitq::coordinator::pipeline::stages::{study_key, KIND_STUDY};
use fitq::coordinator::pipeline::{LeaseConfig, Pipeline, StageCounters};
use fitq::coordinator::{run_study, StudyOptions};

mod common;

const MODEL: &str = "cnn_mnist";

fn study_opt() -> StudyOptions {
    let mut opt = StudyOptions {
        n_configs: 3,
        fp_epochs: 1,
        qat_epochs: 1,
        eval_n: 64,
        seed: 11,
        ..Default::default()
    };
    opt.trace.max_iters = 15;
    opt
}

/// Millisecond-scale lease policy so holder-death takeover happens inside
/// the test budget instead of after the 10-minute production TTL.
fn short_leases() -> LeaseConfig {
    LeaseConfig {
        ttl: Duration::from_millis(150),
        poll: Duration::from_millis(10),
        max_wait: Duration::from_secs(5),
    }
}

fn tmp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fitq_fault_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn pipeline(dir: &Path) -> Pipeline {
    let mut p = Pipeline::new(dir).expect("pipeline");
    p.set_lease_config(short_leases());
    p
}

/// Fresh results root seeded with the baseline's cached stage artifacts —
/// everything except the study entry, which each scenario must produce
/// (or fail to produce) under its own fault.
fn seeded_dir(tag: &str, baseline_dir: &Path) -> PathBuf {
    let dir = tmp_root(tag);
    let cache = dir.join("cache");
    std::fs::create_dir_all(&cache).unwrap();
    for entry in std::fs::read_dir(baseline_dir.join("cache")).unwrap() {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.ends_with(".bin") && !name.starts_with("study_") {
            std::fs::copy(entry.path(), cache.join(&name)).unwrap();
        }
    }
    dir
}

/// The per-site drill. `spec` goes to `FaultPlan::parse` (so `@N`
/// counting rules are exercised through the real front door); `fired`
/// names the site the scenario must actually trigger.
#[test]
fn every_fault_site_degrades_to_recompute_or_typed_error() {
    let rt = common::runtime();
    let opt = study_opt();

    // fault-free baseline; its stage artifacts seed every scenario
    let base_dir = tmp_root("baseline");
    let base_pipe = pipeline(&base_dir);
    let baseline = run_study(&rt, &base_pipe, MODEL, &opt).expect("baseline study");
    assert!(baseline.failures.is_empty(), "baseline must be clean");
    let baseline_bytes = encode_study(&baseline);

    // `cache.load.read_fail@3` targets the third load of the run — the FP
    // checkpoint's cache read (loads 1-2 are the study's own misses) — so
    // the fault lands on an entry that exists and would otherwise hit.
    let scenarios: &[(&str, &str)] = &[
        (site::CACHE_STORE_SHORT_WRITE, site::CACHE_STORE_SHORT_WRITE),
        (site::CACHE_STORE_HEADER_CORRUPT, site::CACHE_STORE_HEADER_CORRUPT),
        (site::CACHE_STORE_PAYLOAD_CORRUPT, site::CACHE_STORE_PAYLOAD_CORRUPT),
        (site::CACHE_STORE_TMP_WRITE_FAIL, site::CACHE_STORE_TMP_WRITE_FAIL),
        (site::CACHE_STORE_RENAME_FAIL, site::CACHE_STORE_RENAME_FAIL),
        ("cache.load.read_fail@3", site::CACHE_LOAD_READ_FAIL),
        (site::CACHE_LOAD_TORN_READ, site::CACHE_LOAD_TORN_READ),
        (site::LEASE_ACQUIRE_HOLDER_DEATH, site::LEASE_ACQUIRE_HOLDER_DEATH),
        (site::LEASE_ACQUIRE_RECORD_CORRUPT, site::LEASE_ACQUIRE_RECORD_CORRUPT),
        (site::LEASE_RELEASE_UNLINK_FAIL, site::LEASE_RELEASE_UNLINK_FAIL),
        (site::LEASE_TAKEOVER_REAP_FAIL, site::LEASE_TAKEOVER_REAP_FAIL),
        (site::PARALLEL_JOB_PANIC, site::PARALLEL_JOB_PANIC),
        (site::STAGE_COMPUTE_PANIC, site::STAGE_COMPUTE_PANIC),
    ];
    assert!(scenarios.len() >= 10, "the drill must cover the registered sites");

    for (i, (spec, fired)) in scenarios.iter().enumerate() {
        let dir = seeded_dir(&format!("s{i}"), &base_dir);
        if *fired == site::LEASE_TAKEOVER_REAP_FAIL {
            // takeover needs something to take over: a mangled lease left
            // by a "crashed" process at the study's lease path
            let key = study_key(rt.backend_name(), rt.model(MODEL).unwrap(), &opt);
            let cache = pipeline(&dir);
            std::fs::write(cache.cache().lease_path(KIND_STUDY, &key), b"mangled lease").unwrap();
        }

        let scope = fault::scoped(FaultPlan::parse(spec).unwrap());
        let pipe = pipeline(&dir);
        let result = run_study(&rt, &pipe, MODEL, &opt);
        assert!(scope.fired(fired) >= 1, "{spec}: the armed site never fired");
        drop(scope);

        match result {
            Ok(res) if res.failures.is_empty() => {
                // recompute / wait-and-takeover path: output unaffected
                assert_eq!(
                    encode_study(&res),
                    baseline_bytes,
                    "{spec}: faulted run diverged from baseline"
                );
            }
            Ok(res) => {
                // degraded sweep: the failed config is reported, the
                // survivors complete, and the study is NOT cached
                assert_eq!(*fired, site::PARALLEL_JOB_PANIC, "{spec}: unexpected degradation");
                assert_eq!(res.failures.len(), 1, "{spec}: one injected failure");
                assert!(res.failures[0].panicked, "{spec}: must be typed as a panic");
                assert!(!res.failures[0].label.is_empty(), "{spec}: failure must be labeled");
                assert_eq!(res.outcomes.len(), opt.n_configs - 1, "{spec}: survivors complete");
                assert!(
                    pipe.study_cached(&rt, MODEL, &opt).is_none(),
                    "{spec}: a degraded study must never be cached"
                );
            }
            Err(e) => {
                // typed abort: only the whole-stage panic takes this path
                assert_eq!(*fired, site::STAGE_COMPUTE_PANIC, "{spec}: unexpected abort: {e:#}");
                assert!(format!("{e:#}").contains("panicked"), "{spec}: untyped error: {e:#}");
            }
        }

        // recovery: fault gone, fresh pipeline, same store — bit-identical
        let pipe2 = pipeline(&dir);
        let recovered = run_study(&rt, &pipe2, MODEL, &opt)
            .unwrap_or_else(|e| panic!("{spec}: recovery run failed: {e:#}"));
        assert_eq!(
            encode_study(&recovered),
            baseline_bytes,
            "{spec}: recovery not bit-identical to the fault-free baseline"
        );

        if *fired == site::CACHE_STORE_RENAME_FAIL {
            // the orphaned temp file from the failed publish is gc fodder
            let g = pipe2.cache().gc(Duration::ZERO).unwrap();
            assert!(g.tmp_reaped >= 1, "{spec}: orphan tmp must be reaped");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_dir_all(&base_dir).ok();
}

/// Two pipelines (one per thread, as two processes would) race the same
/// cold study: the lease layer must hand each stage to exactly one of
/// them, the loser must serve the winner's published bytes, and both must
/// agree bit-for-bit.
#[test]
fn concurrent_pipelines_compute_each_stage_exactly_once() {
    // empty plan fires nothing but holds the process-wide fault scope, so
    // this test never overlaps an armed scenario on a sibling test thread
    let _quiet = fault::scoped(FaultPlan::default());
    let dir = tmp_root("concurrent");
    let opt = study_opt();
    let counters = Arc::new(StageCounters::default());
    let barrier = Arc::new(Barrier::new(2));
    // production-scale TTL (no takeover mid-compute), fast polling
    let lease = LeaseConfig {
        ttl: Duration::from_secs(600),
        poll: Duration::from_millis(10),
        max_wait: Duration::from_secs(600),
    };

    let mut agreed: Vec<Vec<u8>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let dir = &dir;
                let opt = &opt;
                let counters = counters.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    let rt = common::runtime();
                    let mut pipe = Pipeline::with_counters(dir, counters).expect("pipeline");
                    pipe.set_lease_config(lease);
                    barrier.wait();
                    let res = run_study(&rt, &pipe, MODEL, opt).expect("racing study");
                    encode_study(&res)
                })
            })
            .collect();
        for h in handles {
            agreed.push(h.join().expect("racer thread"));
        }
    });

    assert_eq!(agreed[0], agreed[1], "racers must agree byte-for-byte");
    assert_eq!(counters.train_fp_computed(), 1, "FP training must run exactly once");
    assert_eq!(counters.sensitivity_computed(), 1, "sensitivity must run exactly once");
    assert_eq!(counters.study_computed(), 1, "the sweep must run exactly once");
    assert!(counters.claims_won() >= 3, "each stage needs a claim winner");
    std::fs::remove_dir_all(&dir).ok();
}
