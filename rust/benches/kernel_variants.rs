//! Bench: the SIMD kernel-variant record (`make bench-kernels`).
//!
//! Two sections, both on the native backend:
//!
//! 1. Per-kernel nominal GFLOP/s for every hot kernel (direct conv
//!    forward/backward, the im2col lowerings, the G-GEMM backward-x
//!    path, the im2col/col2im packers) at three study-layer shapes,
//!    across every SIMD variant this host detects. These are the raw
//!    numbers the autotuner's winners should be explainable from.
//! 2. `train_epoch` wall clock per model across the `FITQ_NATIVE_KERNEL`
//!    settings (plus the scalar `ops::reference` "before" leg) — the
//!    whole-net before/after record.
//!
//! Timing is min-of-N, not mean: the minimum rejects scheduler noise on
//! loaded hosts, and these kernels have no warm-up-dependent state.
//! Results land in `BENCH_kernels.json` at the repo root; the committed
//! point was measured via the C mirror (`tools/cmirror/kernels.c`) on
//! the single-core container this repo grows in — rerun this bench on a
//! real host to refresh it.

use std::time::Instant;

use fitq::bench_util::black_box;
use fitq::coordinator::ModelState;
use fitq::data::{EpochBatch, SynthClass};
use fitq::native::gemm::{self, Init};
use fitq::native::simd::{self, Isa};
use fitq::runtime::{Arg, Runtime};
use fitq::tensor::Pcg32;

/// Best-of-`reps` seconds for one call of `f` (after one warmup call).
fn min_time_s(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn randv(len: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, 19);
    (0..len).map(|_| rng.normal() * scale).collect()
}

/// Post-ReLU-like data: ~half exact zeros, so the zero-skip paths are
/// priced in exactly as they are in a real net.
fn sparse_randv(len: usize, seed: u64) -> Vec<f32> {
    let mut v = randv(len, 1.0, seed);
    for x in v.iter_mut() {
        *x = x.max(0.0);
    }
    v
}

struct KernelRow {
    kernel: &'static str,
    shape: &'static str,
    variants: Vec<(Isa, f64)>,
}

/// Study-layer geometries: first conv of each model plus the mid cifar
/// conv (the widest vector axis the nets have).
const SHAPES: &[(&str, usize, usize, usize, usize, usize)] = &[
    ("b32 32x32 3->16 (cifar L0)", 32, 32, 32, 3, 16),
    ("b32 16x16 16->32 (cifar L1)", 32, 16, 16, 16, 32),
    ("b32 16x16 1->8 (mnist L0)", 32, 16, 16, 1, 8),
];

fn kernel_rows() -> Vec<KernelRow> {
    const REPS: usize = 5;
    let isas = Isa::detected();
    let mut rows = Vec::new();
    for &(label, n, h, w, cin, cout) in SHAPES {
        let x = sparse_randv(n * h * w * cin, 2);
        let wgt = randv(9 * cin * cout, 0.3, 3);
        let bias = randv(cout, 0.1, 4);
        let dout = randv(n * h * w * cout, 1.0, 5);
        let m = n * h * w;
        let k = 9 * cin;
        // nominal FLOPs: the dense count, ignoring the zero-skip — so a
        // variant that skips more work shows up as *higher* GFLOP/s,
        // which is exactly the ranking the autotuner needs
        let conv_flops = 2.0 * (m * k * cout) as f64;
        let pack_flops = (m * k) as f64; // one move/add per G cell

        let mut out = vec![0.0f32; m * cout];
        let mut dw = vec![0.0f32; k * cout];
        let mut db = vec![0.0f32; cout];
        let mut dx = vec![0.0f32; n * h * w * cin];
        let mut a = Vec::new();
        let mut bt = Vec::new();
        let mut g = vec![0.0f32; m * k];

        let mut per_isa = |f: &mut dyn FnMut(Isa)| -> Vec<(Isa, f64)> {
            isas.iter().map(|&isa| (isa, min_time_s(REPS, || f(isa)))).collect()
        };

        let direct_fwd = per_isa(&mut |isa| {
            gemm::conv2d_direct(&x, n, h, w, cin, &wgt, cout, &bias, &mut out, 1, isa);
            black_box(out[0]);
        });
        rows.push(KernelRow {
            kernel: "conv2d_fwd_direct",
            shape: label,
            variants: direct_fwd.iter().map(|&(i, s)| (i, conv_flops / s / 1e9)).collect(),
        });

        let im2col_fwd = per_isa(&mut |isa| {
            gemm::im2col3x3(&x, n, h, w, cin, &mut a);
            gemm::sgemm(m, cout, k, &a, &wgt, Init::Bias(&bias), &mut out, 1, isa);
            black_box(out[0]);
        });
        rows.push(KernelRow {
            kernel: "conv2d_fwd_im2col",
            shape: label,
            variants: im2col_fwd.iter().map(|&(i, s)| (i, conv_flops / s / 1e9)).collect(),
        });

        let direct_bwd_w = per_isa(&mut |isa| {
            dw.fill(0.0);
            db.fill(0.0);
            gemm::conv2d_bwd_w_direct(&x, n, h, w, cin, &dout, cout, &mut dw, &mut db, 1, isa);
            black_box(dw[0]);
        });
        rows.push(KernelRow {
            kernel: "conv2d_bwd_w_direct",
            shape: label,
            variants: direct_bwd_w.iter().map(|&(i, s)| (i, conv_flops / s / 1e9)).collect(),
        });

        let im2col_bwd_w = per_isa(&mut |isa| {
            dw.fill(0.0);
            db.fill(0.0);
            gemm::im2col3x3(&x, n, h, w, cin, &mut a);
            gemm::sgemm_atb(m, cout, k, &a, &dout, &mut dw, 1, isa);
            simd::col_sum(isa, &mut db, &dout, cout);
            black_box(dw[0]);
        });
        rows.push(KernelRow {
            kernel: "conv2d_bwd_w_im2col",
            shape: label,
            variants: im2col_bwd_w.iter().map(|&(i, s)| (i, conv_flops / s / 1e9)).collect(),
        });

        let bwd_x = per_isa(&mut |isa| {
            gemm::transpose(&wgt, k, cout, &mut bt);
            gemm::sgemm(m, k, cout, &dout, &bt, Init::Zero, &mut g, 1, isa);
            gemm::col2im3x3(&g, n, h, w, cin, &mut dx, 1, isa);
            black_box(dx[0]);
        });
        rows.push(KernelRow {
            kernel: "conv2d_bwd_x_gemm",
            shape: label,
            variants: bwd_x.iter().map(|&(i, s)| (i, conv_flops / s / 1e9)).collect(),
        });

        gemm::im2col3x3(&x, n, h, w, cin, &mut a);
        let col2im = per_isa(&mut |isa| {
            gemm::col2im3x3(&a, n, h, w, cin, &mut dx, 1, isa);
            black_box(dx[0]);
        });
        rows.push(KernelRow {
            kernel: "col2im3x3",
            shape: label,
            variants: col2im.iter().map(|&(i, s)| (i, pack_flops / s / 1e9)).collect(),
        });

        // the pack is a pure gather/copy — it has no SIMD variants
        let pack_s = min_time_s(REPS, || {
            gemm::im2col3x3(&x, n, h, w, cin, &mut a);
            black_box(a[0]);
        });
        rows.push(KernelRow {
            kernel: "im2col3x3",
            shape: label,
            variants: vec![(Isa::Scalar, pack_flops / pack_s / 1e9)],
        });
    }
    rows
}

/// Min-of-`reps` `train_epoch` wall (ms) on a fresh serial runtime.
fn train_epoch_ms(model: &str, reps: usize) -> f64 {
    let rt = Runtime::native_with_threads(1).unwrap();
    let mm = rt.model(model).unwrap().clone();
    let exe = rt.load(model, "train_epoch").unwrap();
    let st = ModelState::init(&rt, model, 7).unwrap();
    let ds = if model.starts_with("cnn_cifar") {
        SynthClass::syncifar(7)
    } else {
        SynthClass::synmnist(7)
    };
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    1e3 * min_time_s(reps, || {
        black_box(
            exe.run(&[
                Arg::F32(&st.params),
                Arg::F32(&st.m),
                Arg::F32(&st.v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap(),
        );
    })
}

fn main() -> anyhow::Result<()> {
    // keep tuner artifacts out of the checkout: the auto leg resolves its
    // route table under the results root
    let results = std::env::temp_dir().join(format!("fitq_bench_kern_{}", std::process::id()));
    std::env::set_var("FITQ_RESULTS", &results);

    let isas = Isa::detected();
    println!("# per-kernel nominal GFLOP/s (min-of-5, threads=1)\n");
    let rows = kernel_rows();
    for r in &rows {
        let cols: Vec<String> =
            r.variants.iter().map(|(i, g)| format!("{} {:>6.2}", i.name(), g)).collect();
        println!("  {:<20} {:<30} {}", r.kernel, r.shape, cols.join("  "));
    }

    println!("\n# train_epoch (K=10 Adam steps, B=32) across kernel variants, min-of-7\n");
    const TRAIN_REPS: usize = 7;
    let mut train_rows = Vec::new();
    for model in ["cnn_mnist", "cnn_cifar"] {
        // "before" leg: PR-4's scalar loop nests via the reference hatch
        std::env::set_var("FITQ_NATIVE_REFERENCE", "1");
        let reference_ms = train_epoch_ms(model, TRAIN_REPS);
        std::env::remove_var("FITQ_NATIVE_REFERENCE");
        let mut legs: Vec<(String, f64)> = Vec::new();
        for isa in &isas {
            std::env::set_var("FITQ_NATIVE_KERNEL", isa.name());
            legs.push((isa.name().to_string(), train_epoch_ms(model, TRAIN_REPS)));
        }
        std::env::set_var("FITQ_NATIVE_KERNEL", "auto");
        let auto_ms = train_epoch_ms(model, TRAIN_REPS);
        std::env::remove_var("FITQ_NATIVE_KERNEL");
        let scalar_ms = legs[0].1;
        let cols: Vec<String> =
            legs.iter().map(|(n, ms)| format!("{n} {ms:.3} ms")).collect();
        println!(
            "  {model}: ref {reference_ms:.3} ms | {} | auto {auto_ms:.3} ms \
             (auto vs ref {:.2}x, vs scalar {:.2}x)",
            cols.join(" | "),
            reference_ms / auto_ms,
            scalar_ms / auto_ms,
        );
        train_rows.push((model, reference_ms, legs, auto_ms));
    }

    // -- record the trajectory point --------------------------------------
    let kernel_json: Vec<String> = rows
        .iter()
        .map(|r| {
            let vars: Vec<String> =
                r.variants.iter().map(|(i, g)| format!("\"{}\": {g:.3}", i.name())).collect();
            format!(
                "{{\"kernel\": \"{}\", \"shape\": \"{}\", \"variants\": {{{}}}}}",
                r.kernel,
                r.shape,
                vars.join(", ")
            )
        })
        .collect();
    let train_json: Vec<String> = train_rows
        .iter()
        .map(|(model, reference_ms, legs, auto_ms)| {
            let per_isa: Vec<String> =
                legs.iter().map(|(n, ms)| format!("\"{n}_ms\": {ms:.3}")).collect();
            format!(
                "{{\"model\": \"{model}\", \"reference_ms\": {reference_ms:.3}, {}, \
                 \"auto_ms\": {auto_ms:.3}, \
                 \"speedup_auto_vs_reference\": {:.2}, \"speedup_auto_vs_scalar\": {:.2}}}",
                per_isa.join(", "),
                reference_ms / auto_ms,
                legs[0].1 / auto_ms,
            )
        })
        .collect();
    let isa_names: Vec<String> = isas.iter().map(|i| format!("\"{}\"", i.name())).collect();
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    // the routes object records the tuner's per-op winner at the widest
    // class (the headline routing; the full table is per width class)
    let table = fitq::native::tune::tune(1);
    let routes: Vec<String> = fitq::native::tune::OPS
        .iter()
        .map(|&op| {
            let c = table.choice(op, 64);
            format!("\"{}\": \"{}/{}\"", op.name(), c.lowering.name(), c.isa.name())
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"kernel_variants\",\n  \"status\": \"measured\",\n  \
         \"host\": {{\"arch\": \"{}\", \"isas\": [{}], \"cores\": {cores}}},\n  \
         \"routes\": {{{}}},\n  \
         \"kernels\": [\n    {}\n  ],\n  \
         \"train_epoch\": [\n    {}\n  ]\n}}\n",
        std::env::consts::ARCH,
        isa_names.join(", "),
        routes.join(", "),
        kernel_json.join(",\n    "),
        train_json.join(",\n    "),
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    std::fs::write(path, &json).expect("write BENCH_kernels.json");
    println!("\nwrote {path}");
    let _ = std::fs::remove_dir_all(&results);
    Ok(())
}
