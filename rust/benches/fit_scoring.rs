//! Bench: the table-driven FIT scoring engine vs the naive paths.
//!
//! Pure Rust — runs on any checkout, no artifacts or PJRT needed. Three
//! measurements on a production-shaped synthetic problem (48 weight + 16
//! activation blocks, the paper's {8,6,4,3} precision set):
//!
//! 1. single-config scoring: naive `fit()` vs `FitTable::score`
//!    (acceptance target: >= 10x);
//! 2. batch throughput: `score_batch` configs/sec at 1k / 100k / 1M
//!    packed configs, serial and fanned over the worker pool;
//! 3. budgeted allocation: naive clone-and-rescore greedy vs the heap
//!    walk on a 64-block instance (equivalence asserted, then timed).
//!
//! Results are written to `BENCH_fit_scoring.json` at the repo root —
//! the perf-trajectory record `make bench-scoring` refreshes.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{greedy_allocate, greedy_allocate_naive};
use fitq::metrics::{fit, FitTable, PackedConfig, SensitivityInputs};
use fitq::quant::{model_bits, BitConfig, PRECISIONS};
use fitq::tensor::Pcg32;

fn synth(lw: usize, la: usize, seed: u64) -> (SensitivityInputs, Vec<usize>) {
    let mut r = Pcg32::new(seed, 0xbe9c);
    let w_traces: Vec<f64> = (0..lw).map(|_| r.uniform_in(0.01, 25.0) as f64).collect();
    let w_hi: Vec<f64> = (0..lw).map(|_| r.uniform_in(0.05, 2.0) as f64).collect();
    let w_lo: Vec<f64> = w_hi.iter().map(|&x| -x).collect();
    let a_traces: Vec<f64> = (0..la).map(|_| r.uniform_in(0.01, 8.0) as f64).collect();
    let a_hi: Vec<f64> = (0..la).map(|_| r.uniform_in(0.5, 8.0) as f64).collect();
    let sizes: Vec<usize> = (0..lw).map(|_| 256 + r.below(65_536) as usize).collect();
    let s = SensitivityInputs {
        bn_gamma: vec![None; lw],
        a_lo: vec![0.0; la],
        w_traces,
        a_traces,
        w_lo,
        w_hi,
        a_hi,
    };
    (s, sizes)
}

fn main() {
    const LW: usize = 48;
    const LA: usize = 16;
    const N_UNQ: usize = 64;
    let (s, sizes) = synth(LW, LA, 11);
    let table = FitTable::new(&s, &sizes, N_UNQ, &PRECISIONS);

    // -- 1. single-config scoring (amortized over 1000 configs/iter) ------
    let mut rng = Pcg32::new(7, 0x5c0e);
    let k = 1000usize;
    let cfgs: Vec<BitConfig> =
        (0..k).map(|_| BitConfig::random(LW, LA, &PRECISIONS, &mut rng)).collect();
    let packed: Vec<PackedConfig> = cfgs.iter().map(|c| table.pack(c)).collect();
    // sanity: the table must agree with the naive metric bit-for-bit
    for (c, p) in cfgs.iter().zip(&packed) {
        assert_eq!(table.score(p).to_bits(), fit(&s, c).to_bits());
    }

    println!("# fit_scoring — table engine vs naive ({LW}w + {LA}a blocks)\n");
    let r_naive = bench("naive fit() x1000", 3, 30, || {
        let mut acc = 0.0;
        for c in &cfgs {
            acc += fit(&s, c);
        }
        black_box(acc);
    });
    let r_table = bench("FitTable::score x1000", 3, 30, || {
        let mut acc = 0.0;
        for p in &packed {
            acc += table.score(p);
        }
        black_box(acc);
    });
    let single_speedup = r_naive.mean_ns / r_table.mean_ns;
    println!("  -> single-config speedup: {single_speedup:.1}x\n");

    // -- 2. batch throughput ----------------------------------------------
    let mut batch_rows = Vec::new();
    for &n in &[1_000usize, 100_000, 1_000_000] {
        let mut brng = Pcg32::new(n as u64, 0xba7c);
        let bp: Vec<PackedConfig> = (0..n)
            .map(|_| table.pack(&BitConfig::random(LW, LA, &PRECISIONS, &mut brng)))
            .collect();
        for &jobs in &[1usize, 0] {
            let iters = if n >= 1_000_000 { 3 } else { 10 };
            let r = bench(&format!("score_batch n={n} jobs={jobs}"), 1, iters, || {
                black_box(table.score_batch(&bp, jobs));
            });
            let cps = n as f64 * 1e9 / r.mean_ns;
            batch_rows.push((n, jobs, cps));
        }
    }
    println!();

    // -- 3. greedy allocation: naive rescan vs heap walk -------------------
    let (gs, gsizes) = synth(64, 16, 23);
    let gfull = model_bits(&gsizes, N_UNQ, &BitConfig::uniform(64, 16, 8));
    let budget = gfull * 45 / 100;
    let a = greedy_allocate_naive(&gs, &gsizes, N_UNQ, &PRECISIONS, budget).unwrap();
    let b = greedy_allocate(&gs, &gsizes, N_UNQ, &PRECISIONS, budget).unwrap();
    assert_eq!(a.cfg, b.cfg, "heap greedy must match the naive reference");
    assert_eq!(a.fit.to_bits(), b.fit.to_bits());
    let r_gnaive = bench("greedy naive (64 blocks, 45% budget)", 1, 10, || {
        black_box(greedy_allocate_naive(&gs, &gsizes, N_UNQ, &PRECISIONS, budget));
    });
    let r_gheap = bench("greedy heap  (64 blocks, 45% budget)", 1, 10, || {
        black_box(greedy_allocate(&gs, &gsizes, N_UNQ, &PRECISIONS, budget));
    });
    let greedy_speedup = r_gnaive.mean_ns / r_gheap.mean_ns;
    println!("  -> greedy speedup: {greedy_speedup:.1}x");

    // -- record the trajectory point ---------------------------------------
    let mut batch_json = String::new();
    for (i, (n, jobs, cps)) in batch_rows.iter().enumerate() {
        if i > 0 {
            batch_json.push_str(",\n    ");
        }
        batch_json.push_str(&format!(
            "{{\"n\": {n}, \"jobs\": {jobs}, \"configs_per_sec\": {cps:.1}}}"
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"fit_scoring\",\n  \"status\": \"measured\",\n  \
         \"shape\": {{\"weight_blocks\": {LW}, \"act_blocks\": {LA}, \
         \"precisions\": [8, 6, 4, 3]}},\n  \
         \"single\": {{\"naive_ns_per_config\": {:.1}, \"table_ns_per_config\": {:.1}, \
         \"speedup\": {:.2}}},\n  \
         \"batch\": [\n    {batch_json}\n  ],\n  \
         \"greedy\": {{\"blocks\": 64, \"naive_ns\": {:.0}, \"heap_ns\": {:.0}, \
         \"speedup\": {:.2}}}\n}}\n",
        r_naive.mean_ns / k as f64,
        r_table.mean_ns / k as f64,
        single_speedup,
        r_gnaive.mean_ns,
        r_gheap.mean_ns,
        greedy_speedup,
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fit_scoring.json");
    std::fs::write(path, &json).expect("write BENCH_fit_scoring.json");
    println!("\nwrote {path}");
}
