//! Bench: Table 2 / Figs 3-4 pipeline stages — the per-configuration cost
//! of the rank-correlation study: QAT epoch, quantized eval, metric
//! evaluation. These dominate the wall-clock of the 100-config studies.
//!
//! Run with `cargo bench --bench table2_pipeline` — PJRT when artifacts
//! are present, else the native backend.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{dataset_for, gather, ModelState, TraceOptions, Trainer};
use fitq::data::EvalSet;
use fitq::metrics::Metric;
use fitq::quant::{BitConfig, BitConfigSampler, PRECISIONS};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // PJRT over artifacts when present, else the native interpreter
    // (FITQ_BACKEND overrides)
    let rt = Runtime::from_env()?;
    println!("# backend: {}", rt.backend_name());
    let model = "cnn_mnist";
    let mm = rt.model(model)?.clone();
    let ds = dataset_for(&rt, model, 0xda7a)?;
    let mut trainer = Trainer::new(&rt, ds.as_ref());
    let mut st = ModelState::init(&rt, model, 0)?;
    trainer.train(&mut st, 10)?;
    let ev = EvalSet::materialize(ds.as_ref(), 512);
    let sens = gather(&trainer, ds.as_ref(), &st, &ev, TraceOptions::default())?;
    let cfg = BitConfig::uniform(mm.n_weight_blocks(), mm.n_act_blocks(), 4);

    println!("# Table-2 pipeline bench ({model})\n");
    bench("qat_epoch (10 steps, bs32)", 1, 8, || {
        let mut s2 = st.clone();
        s2.reset_optimizer();
        trainer.qat_train(&mut s2, &cfg, &sens.act, 1).unwrap();
    });
    bench("qat_eval (512 samples)", 1, 8, || {
        black_box(trainer.evaluate_q(&st, &ev, &cfg, &sens.act).unwrap());
    });
    bench("fp_eval (512 samples)", 1, 8, || {
        black_box(trainer.evaluate(&st, &ev).unwrap());
    });

    // metric evaluation: the "free" part FIT buys (vs training a config)
    let mut sampler =
        BitConfigSampler::new(mm.n_weight_blocks(), mm.n_act_blocks(), &PRECISIONS, 1);
    let configs = sampler.take(1000);
    bench("metric zoo x 1000 configs", 1, 20, || {
        for c in &configs {
            for m in Metric::ALL {
                black_box(m.eval(&sens.inputs, c));
            }
        }
    });
    Ok(())
}
