//! Bench: Table 1 / Table 4 — per-iteration cost of the EF and Hutchinson
//! trace estimators across the scale ladder (the end-to-end measurement
//! the paper times on a 2080 Ti; here via CPU PJRT).
//!
//! Run with `cargo bench --bench table1_traces` (needs `make artifacts`).

use fitq::bench_util::bench;
use fitq::coordinator::{dataset_for, Estimator, ModelState, TraceEngine, TraceOptions, Trainer};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping bench: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(root)?;
    println!("# Table-1/4 bench: estimator cost per iteration (bs=32)\n");
    for model in ["cnn_s", "cnn_m", "cnn_l"] {
        let ds = dataset_for(&rt, model, 0xda7a)?;
        let mut trainer = Trainer::new(&rt, ds.as_ref());
        let mut st = ModelState::init(&rt, model, 0)?;
        trainer.train(&mut st, 3)?; // lightly trained is enough for cost
        let engine = TraceEngine::new(&rt, ds.as_ref());
        for (est, tag) in [
            (Estimator::EmpiricalFisher, "ef"),
            (Estimator::Hutchinson, "hessian"),
        ] {
            let mut seed = 0u64;
            bench(&format!("{model}/{tag}_iteration_bs32"), 1, 8, || {
                seed += 1;
                let o = TraceOptions::fixed_iters(32, 1, seed);
                engine.run(model, &st.params, est, o).unwrap();
            });
        }
    }
    Ok(())
}
