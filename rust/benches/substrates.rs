//! Bench: pure-Rust substrate hot paths (no PJRT) — the L3 costs that
//! surround every dispatch: dataset synthesis, quantizer sweeps,
//! statistics, config sampling and Pareto extraction.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{pareto_front, score};
use fitq::data::{Dataset, EpochBatch, Split, SynthClass, SynthSeg};
use fitq::metrics::SensitivityInputs;
use fitq::quant::{BitConfigSampler, UniformQuantizer, PRECISIONS};
use fitq::stats::{kendall_tau, spearman, RunningStats};
use fitq::tensor::Pcg32;

fn main() {
    println!("# Substrate benches\n");
    let mut rng = Pcg32::new(1, 1);

    // data generation (feeds every scanned epoch)
    let ds = SynthClass::syncifar(1);
    bench("synth_class epoch batch (10x32 cifar)", 2, 10, || {
        black_box(EpochBatch::generate(&ds, 10, 32, 0));
    });
    let seg = SynthSeg::synthshapes(1);
    let mut x = vec![0.0f32; seg.sample_len()];
    let mut y = vec![0i32; seg.label_len()];
    bench("synth_seg sample (32x32x3 + labels)", 10, 100, || {
        seg.sample(Split::Train, 7, &mut x, &mut y);
        black_box(&x);
    });

    // quantizer sweep (fig5/fig9 analysis path)
    let weights: Vec<f32> = (0..100_000).map(|_| rng.normal()).collect();
    bench("uniform quantize-dequantize 100k params", 2, 20, || {
        let q = UniformQuantizer::fit(&weights, 4);
        black_box(q.empirical_noise_power(&weights));
    });

    // statistics
    let xs: Vec<f64> = (0..5_000).map(|_| rng.normal() as f64).collect();
    let ys: Vec<f64> = xs.iter().map(|v| v + rng.normal() as f64).collect();
    bench("spearman n=5000", 2, 20, || {
        black_box(spearman(&xs, &ys));
    });
    bench("kendall_tau n=1000", 2, 10, || {
        black_box(kendall_tau(&xs[..1000], &ys[..1000]));
    });
    bench("welford push x 10k", 2, 20, || {
        let mut s = RunningStats::new();
        for &v in &xs {
            s.push(v);
        }
        for &v in &ys {
            s.push(v);
        }
        black_box(s.mean());
    });

    // config sampling + FIT scoring + Pareto (mpq_search inner loop)
    let sens = SensitivityInputs {
        w_traces: vec![5.0, 2.0, 1.0, 0.2],
        a_traces: vec![3.0, 1.0, 0.4],
        w_lo: vec![-1.0; 4],
        w_hi: vec![1.0; 4],
        a_lo: vec![0.0; 3],
        a_hi: vec![6.0; 3],
        bn_gamma: vec![None; 4],
    };
    let sizes = vec![432usize, 4608, 9216, 2560];
    bench("sample+score+pareto 2000 configs", 1, 10, || {
        let mut sampler = BitConfigSampler::new(4, 3, &PRECISIONS, 9);
        let pts: Vec<_> = sampler
            .take(2000)
            .into_iter()
            .map(|c| score(&sens, &sizes, 100, c))
            .collect();
        black_box(pareto_front(&pts));
    });

    // rng primitives
    bench("pcg32 normal x 1M", 1, 10, || {
        let mut r = Pcg32::new(3, 3);
        let mut acc = 0.0f32;
        for _ in 0..1_000_000 {
            acc += r.normal();
        }
        black_box(acc);
    });
}
