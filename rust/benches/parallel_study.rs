//! Bench: the PR-1 before/after measurement — `run_study`'s per-config
//! sweep at `jobs = 1` (the old strictly sequential evaluator) vs parallel
//! job counts. The sweep is the wall-clock bottleneck of Table 2 / Fig 4
//! (hundreds of QAT fine-tunes), so the expected shape is near-linear
//! scaling until PJRT dispatches saturate memory bandwidth.
//!
//! Run with `cargo bench --bench parallel_study` (needs `make artifacts`).
//! Also prints the pure-pool overhead measurement, which runs everywhere.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{derive_seed, run_pool, run_study, Pipeline, StudyOptions};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // pool overhead on pure-Rust work (no PJRT): runs on any checkout
    println!("# parallel pool: pure-Rust scaling (64 jobs x 2M mixes)\n");
    for jobs in [1usize, 2, 4, 8] {
        bench(&format!("pool 64 seeded mixes jobs={jobs}"), 1, 5, || {
            let out = run_pool(
                64,
                jobs,
                || Ok(()),
                |_, i| {
                    let mut x = derive_seed(7, i as u64);
                    for _ in 0..2_000_000 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    }
                    Ok(x)
                },
            )
            .unwrap();
            black_box(out);
        });
    }

    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("\nskipping run_study bench: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(root)?;
    let base = StudyOptions {
        n_configs: 8,
        fp_epochs: 4,
        qat_epochs: 1,
        eval_n: 256,
        seed: 3,
        ..Default::default()
    };
    println!("\n# run_study cnn_mnist (8 configs, 1 QAT epoch) serial vs parallel\n");
    // fresh results dir per timed call: the pipeline cache would otherwise
    // turn every iteration after the first into a cache read
    let cold_dir = std::env::temp_dir().join(format!("fitq_bench_cold_{}", std::process::id()));
    for jobs in [1usize, 2, 4] {
        let opt = StudyOptions { jobs, ..base.clone() };
        bench(&format!("run_study 8 configs jobs={jobs} (cold)"), 0, 3, || {
            std::fs::remove_dir_all(&cold_dir).ok();
            let pipe = Pipeline::new(&cold_dir).unwrap();
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        });
    }

    // the pipeline-cache payoff: identical study served from the store
    println!("\n# run_study warm (stage + study cache hits)\n");
    let warm_dir = std::env::temp_dir().join(format!("fitq_bench_warm_{}", std::process::id()));
    std::fs::remove_dir_all(&warm_dir).ok();
    {
        let pipe = Pipeline::new(&warm_dir)?;
        let opt = StudyOptions { jobs: 1, ..base.clone() };
        run_study(&rt, &pipe, "cnn_mnist", &opt)?; // populate
        bench("run_study 8 configs warm cache", 1, 5, || {
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        });
    }
    std::fs::remove_dir_all(&cold_dir).ok();
    std::fs::remove_dir_all(&warm_dir).ok();
    Ok(())
}
