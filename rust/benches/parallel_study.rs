//! Bench: `run_study`'s per-config sweep at `jobs = 1` (the old strictly
//! sequential evaluator) vs parallel job counts, plus the warm-cache
//! path. The sweep is the wall-clock bottleneck of Table 2 / Fig 4
//! (hundreds of QAT fine-tunes), so the expected shape is near-linear
//! scaling until dispatches saturate memory bandwidth.
//!
//! Backend-aware: runs on PJRT when `artifacts/` is present, else on the
//! zero-setup native interpreter (`FITQ_BACKEND` overrides; `make
//! bench-native` pins native). Results land in
//! `BENCH_parallel_study.json` at the repo root — the perf-trajectory
//! record for this path. Also prints the pure-pool overhead measurement.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{derive_seed, run_pool, run_study, Pipeline, StudyOptions};
use fitq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // pool overhead on pure-Rust work (no backend): runs on any checkout
    println!("# parallel pool: pure-Rust scaling (64 jobs x 2M mixes)\n");
    let mut pool_rows = Vec::new();
    for jobs in [1usize, 2, 4, 8] {
        let r = bench(&format!("pool 64 seeded mixes jobs={jobs}"), 1, 5, || {
            let out = run_pool(
                64,
                jobs,
                || Ok(()),
                |_, i| {
                    let mut x = derive_seed(7, i as u64);
                    for _ in 0..2_000_000 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    }
                    Ok(x)
                },
            )
            .unwrap();
            black_box(out);
        });
        pool_rows.push((jobs, r.mean_ns));
    }

    let rt = Runtime::from_env()?;
    println!(
        "\n# run_study cnn_mnist (8 configs, 1 QAT epoch) on the {} backend\n",
        rt.backend_name()
    );
    let base = StudyOptions {
        n_configs: 8,
        fp_epochs: 4,
        qat_epochs: 1,
        eval_n: 256,
        seed: 3,
        ..Default::default()
    };
    // fresh results dir per timed call: the pipeline cache would otherwise
    // turn every iteration after the first into a cache read
    let cold_dir = std::env::temp_dir().join(format!("fitq_bench_cold_{}", std::process::id()));
    let mut study_rows = Vec::new();
    for jobs in [1usize, 2, 4] {
        let opt = StudyOptions { jobs, ..base.clone() };
        let r = bench(&format!("run_study 8 configs jobs={jobs} (cold)"), 0, 3, || {
            std::fs::remove_dir_all(&cold_dir).ok();
            let pipe = Pipeline::new(&cold_dir).unwrap();
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        });
        study_rows.push((jobs, r.mean_ns));
    }

    // the pipeline-cache payoff: identical study served from the store
    println!("\n# run_study warm (stage + study cache hits)\n");
    let warm_dir = std::env::temp_dir().join(format!("fitq_bench_warm_{}", std::process::id()));
    std::fs::remove_dir_all(&warm_dir).ok();
    let warm_ns = {
        let pipe = Pipeline::new(&warm_dir)?;
        let opt = StudyOptions { jobs: 1, ..base.clone() };
        run_study(&rt, &pipe, "cnn_mnist", &opt)?; // populate
        bench("run_study 8 configs warm cache", 1, 5, || {
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        })
        .mean_ns
    };
    std::fs::remove_dir_all(&cold_dir).ok();
    std::fs::remove_dir_all(&warm_dir).ok();

    // -- record the trajectory point --------------------------------------
    let row = |rows: &[(usize, f64)]| {
        rows.iter()
            .map(|(j, ns)| format!("{{\"jobs\": {j}, \"mean_s\": {:.4}}}", ns / 1e9))
            .collect::<Vec<_>>()
            .join(",\n    ")
    };
    let speedup = study_rows[0].1 / study_rows.last().unwrap().1;
    let json = format!(
        "{{\n  \"bench\": \"parallel_study\",\n  \"status\": \"measured\",\n  \
         \"backend\": \"{}\",\n  \
         \"pool_64x2M\": [\n    {}\n  ],\n  \
         \"run_study_8cfg_cold\": [\n    {}\n  ],\n  \
         \"study_speedup_j1_to_j4\": {speedup:.2},\n  \
         \"run_study_warm_s\": {:.4}\n}}\n",
        rt.backend_name(),
        row(&pool_rows),
        row(&study_rows),
        warm_ns / 1e9,
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_parallel_study.json");
    std::fs::write(path, &json).expect("write BENCH_parallel_study.json");
    println!("\nwrote {path}");
    Ok(())
}
