//! Bench: the native GEMM kernel layer (scalar-reference vs im2col+GEMM
//! train_epoch, intra-op thread scaling), `run_study`'s per-config sweep
//! at `jobs = 1` vs parallel job counts, and the warm-cache path. The
//! sweep is the wall-clock bottleneck of Table 2 / Fig 4 (hundreds of
//! QAT fine-tunes), so the expected shape is near-linear scaling until
//! dispatches saturate memory bandwidth; the kernel A/B is the
//! before/after record of the GEMM rewrite (ISSUE 5).
//!
//! Backend-aware: runs on PJRT when `artifacts/` is present, else on the
//! zero-setup native interpreter (`FITQ_BACKEND` overrides; `make
//! bench-native` pins native). Results land in
//! `BENCH_parallel_study.json` at the repo root — the perf-trajectory
//! record for this path. Also prints the pure-pool overhead measurement.
//!
//! `FITQ_BENCH_SMOKE=1` (the CI mode, `make bench-smoke`) runs only the
//! kernel A/B at one timed iteration and *asserts* the GEMM path beats
//! the scalar reference — a loud tripwire for kernel-layer perf
//! regressions — without touching the committed JSON.

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::{derive_seed, run_pool, run_study, ModelState, Pipeline, StudyOptions};
use fitq::data::{EpochBatch, SynthClass};
use fitq::runtime::{Arg, Runtime};

/// Mean seconds per `train_epoch` dispatch (K=10 Adam steps, B=32).
fn train_epoch_s(rt: &Runtime, model: &str, label: &str, warmup: usize, iters: usize) -> f64 {
    let mm = rt.model(model).unwrap().clone();
    let exe = rt.load(model, "train_epoch").unwrap();
    let st = ModelState::init(rt, model, 7).unwrap();
    let ds = if model.starts_with("cnn_cifar") {
        SynthClass::syncifar(7)
    } else {
        SynthClass::synmnist(7)
    };
    let (eb, _) = EpochBatch::generate(&ds, mm.train_k, mm.train_b, 0);
    let r = bench(label, warmup, iters, || {
        black_box(
            exe.run(&[
                Arg::F32(&st.params),
                Arg::F32(&st.m),
                Arg::F32(&st.v),
                Arg::F32Scalar(0.0),
                Arg::F32(&eb.xs),
                Arg::I32(&eb.ys),
            ])
            .unwrap(),
        );
    });
    r.mean_ns / 1e9
}

/// The before/after kernel record: scalar-reference vs GEMM train_epoch
/// on the native backend, plus intra-op thread scaling. Returns the JSON
/// object for the `native_train_epoch` field.
fn native_kernel_ab(smoke: bool) -> String {
    // smoke still warms up once and averages 3 iterations: a single cold
    // timed pass on a shared CI runner can flake past the assert floor
    // on scheduler noise alone
    let (warmup, iters) = if smoke { (1, 3) } else { (1, 5) };
    println!("# native train_epoch: scalar reference vs GEMM-layer kernels (before/after)\n");
    let mut rows = Vec::new();
    // smoke uses cnn_cifar: its measured margin (~1.9x) is far enough
    // from the 1.2x floor that CI noise cannot trip a false alarm —
    // cnn_mnist sits at ~1.1x (Amdahl: tiny layers, fixed overhead) and
    // would flap
    let models: &[&str] = if smoke { &["cnn_cifar"] } else { &["cnn_mnist", "cnn_cifar"] };
    for model in models {
        // "before": PR-4's loop nests, via the reference escape hatch
        std::env::set_var("FITQ_NATIVE_REFERENCE", "1");
        let scalar_s = {
            let rt = Runtime::native_with_threads(1).unwrap();
            train_epoch_s(&rt, model, &format!("{model} train_epoch scalar ref"), warmup, iters)
        };
        std::env::remove_var("FITQ_NATIVE_REFERENCE");
        // "after": the GEMM path at increasing intra-op budgets
        let mut gemm_s = Vec::new();
        for threads in [1usize, 2, 4] {
            let rt = Runtime::native_with_threads(threads).unwrap();
            // label via the runtime's own resolved budget, not the loop var
            let label = format!("{model} train_epoch gemm t={}", rt.intra_threads());
            gemm_s.push(train_epoch_s(&rt, model, &label, warmup, iters));
        }
        let speedup = scalar_s / gemm_s[0];
        let intra = gemm_s[0] / gemm_s[2];
        println!(
            "  {model}: scalar -> gemm(t1) {speedup:.2}x, gemm t1 -> t4 {intra:.2}x\n"
        );
        if smoke {
            assert!(
                speedup >= 1.3,
                "kernel perf regression: {model} GEMM-layer train_epoch only {speedup:.2}x \
                 over the scalar reference (floor 1.3x; the C-mirror-measured point is \
                 ~1.7x with the autotuned SIMD routing — see BENCH_kernels.json and \
                 BENCH_parallel_study.json)"
            );
        }
        rows.push(format!(
            "{{\"model\": \"{model}\", \"scalar_ms\": {:.3}, \"gemm_ms_t1\": {:.3}, \
             \"gemm_ms_t2\": {:.3}, \"gemm_ms_t4\": {:.3}, \
             \"speedup_scalar_to_gemm_t1\": {speedup:.2}, \
             \"intra_op_speedup_t1_to_t4\": {intra:.2}}}",
            scalar_s * 1e3,
            gemm_s[0] * 1e3,
            gemm_s[1] * 1e3,
            gemm_s[2] * 1e3,
        ));
    }
    format!("[\n    {}\n  ]", rows.join(",\n    "))
}

fn main() -> anyhow::Result<()> {
    // smoke mode ignores backend resolution entirely: its whole point is
    // the native-kernel tripwire, and native_kernel_ab builds its own
    // native runtimes — an artifacts/ dir must not turn it vacuous
    if std::env::var_os("FITQ_BENCH_SMOKE").is_some() {
        native_kernel_ab(true);
        println!("smoke mode: kernel A/B asserted, JSON left untouched");
        return Ok(());
    }
    let rt = Runtime::from_env()?;
    let native_json = if rt.backend_name() == "native" {
        native_kernel_ab(false)
    } else {
        "null".to_string()
    };

    // pool overhead on pure-Rust work (no backend): runs on any checkout
    println!("# parallel pool: pure-Rust scaling (64 jobs x 2M mixes)\n");
    let mut pool_rows = Vec::new();
    for jobs in [1usize, 2, 4, 8] {
        let r = bench(&format!("pool 64 seeded mixes jobs={jobs}"), 1, 5, || {
            let out = run_pool(
                64,
                jobs,
                || Ok(()),
                |_, i| {
                    let mut x = derive_seed(7, i as u64);
                    for _ in 0..2_000_000 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    }
                    Ok(x)
                },
            )
            .unwrap();
            black_box(out);
        });
        pool_rows.push((jobs, r.mean_ns));
    }

    println!(
        "\n# run_study cnn_mnist (8 configs, 1 QAT epoch) on the {} backend\n",
        rt.backend_name()
    );
    let base = StudyOptions {
        n_configs: 8,
        fp_epochs: 4,
        qat_epochs: 1,
        eval_n: 256,
        seed: 3,
        ..Default::default()
    };
    // fresh results dir per timed call: the pipeline cache would otherwise
    // turn every iteration after the first into a cache read
    let cold_dir = std::env::temp_dir().join(format!("fitq_bench_cold_{}", std::process::id()));
    let mut study_rows = Vec::new();
    for jobs in [1usize, 2, 4] {
        let opt = StudyOptions { jobs, ..base.clone() };
        let r = bench(&format!("run_study 8 configs jobs={jobs} (cold)"), 0, 3, || {
            std::fs::remove_dir_all(&cold_dir).ok();
            let pipe = Pipeline::new(&cold_dir).unwrap();
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        });
        study_rows.push((jobs, r.mean_ns));
    }

    // the pipeline-cache payoff: identical study served from the store
    println!("\n# run_study warm (stage + study cache hits)\n");
    let warm_dir = std::env::temp_dir().join(format!("fitq_bench_warm_{}", std::process::id()));
    std::fs::remove_dir_all(&warm_dir).ok();
    let warm_ns = {
        let pipe = Pipeline::new(&warm_dir)?;
        let opt = StudyOptions { jobs: 1, ..base.clone() };
        run_study(&rt, &pipe, "cnn_mnist", &opt)?; // populate
        bench("run_study 8 configs warm cache", 1, 5, || {
            black_box(run_study(&rt, &pipe, "cnn_mnist", &opt).unwrap());
        })
        .mean_ns
    };
    std::fs::remove_dir_all(&cold_dir).ok();
    std::fs::remove_dir_all(&warm_dir).ok();

    // -- record the trajectory point --------------------------------------
    let row = |rows: &[(usize, f64)]| {
        rows.iter()
            .map(|(j, ns)| format!("{{\"jobs\": {j}, \"mean_s\": {:.4}}}", ns / 1e9))
            .collect::<Vec<_>>()
            .join(",\n    ")
    };
    let speedup = study_rows[0].1 / study_rows.last().unwrap().1;
    let json = format!(
        "{{\n  \"bench\": \"parallel_study\",\n  \"status\": \"measured\",\n  \
         \"backend\": \"{}\",\n  \
         \"native_train_epoch\": {native_json},\n  \
         \"pool_64x2M\": [\n    {}\n  ],\n  \
         \"run_study_8cfg_cold\": [\n    {}\n  ],\n  \
         \"study_speedup_j1_to_j4\": {speedup:.2},\n  \
         \"run_study_warm_s\": {:.4}\n}}\n",
        rt.backend_name(),
        row(&pool_rows),
        row(&study_rows),
        warm_ns / 1e9,
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_parallel_study.json");
    std::fs::write(path, &json).expect("write BENCH_parallel_study.json");
    println!("\nwrote {path}");
    Ok(())
}
