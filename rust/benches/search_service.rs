//! Bench: the search service vs in-process table scoring.
//!
//! The service's pitch is that a resident table makes config-space
//! search latency-bound on scoring, not on table builds — so the
//! numbers that matter are (a) cold-request latency (one train + trace
//! + table build) vs warm-request latency, and (b) how much throughput
//! the service layers (sharding, dominance merge, JSON, TCP) give up
//! against a bare in-process `score_batch` loop over the same table.
//! Acceptance target from the service issue: warm served throughput
//! >= 0.9x the in-process batch scorer.
//!
//! Needs only the native backend (a real `cnn_mnist` study at one FP
//! epoch and two trace iterations — cheap, but a *real* pipeline, so
//! cold latency is honest). Equivalence is asserted before anything is
//! timed: the served front must be bit-identical to the in-process
//! sweep at every shard count tried here.
//!
//! Results go to `BENCH_search_service.json` at the repo root — the
//! perf-trajectory record `make bench-search` refreshes.

use std::sync::Arc;
use std::time::Instant;

use fitq::bench_util::{bench, black_box};
use fitq::coordinator::service::{
    bind, parse_request, query, sample_indices_into, serve_on, ServiceConfig, ServiceCore,
    ServiceWorker,
};
use fitq::coordinator::{pareto_front_scores, ParetoAccumulator};
use fitq::metrics::{FitTable, PackedConfig};
use fitq::quant::PRECISIONS;
use fitq::runtime::{BackendSpec, Json};

const MODEL: &str = "cnn_mnist";
const SAMPLES: u64 = 200_000;

fn study_json() -> String {
    format!(
        r#"{{"model":"{MODEL}","fp_epochs":1,"seed":0,"trace":{{"batch":8,"min_iters":2,"max_iters":2}}}}"#
    )
}

fn search_line(samples: u64, shards: Option<usize>, stream: bool) -> String {
    let shards = shards.map(|k| format!(r#","shards":{k}"#)).unwrap_or_default();
    format!(
        r#"{{"method":"search","study":{},"mode":"random","samples":{samples},"seed":9{shards},"stream":{stream}}}"#,
        study_json()
    )
}

/// Run one request in-process and return every emitted line.
fn exec(core: &ServiceCore, w: &ServiceWorker, line: &str) -> Vec<String> {
    let req = parse_request(line).expect("request parses");
    let mut out: Vec<String> = Vec::new();
    core.execute(w, &req, &mut |l: &str| {
        out.push(l.to_string());
        Ok(())
    })
    .expect("in-process transport");
    out
}

fn invariant(line: &str) -> &str {
    &line[..line.rfind(",\"metrics\":").expect("metrics trailer")]
}

fn main() {
    let dir = std::env::temp_dir().join(format!("fitq_bench_serve_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let spec = BackendSpec::Native { threads: 1, zoo: Vec::new() };
    let core = Arc::new(ServiceCore::new(
        spec,
        &dir,
        ServiceConfig { jobs: 0, table_capacity: 8, shard_target: 16_384 },
    ));
    let worker = core.worker().expect("worker");

    println!("# search_service — served vs in-process scoring ({MODEL}, {SAMPLES} samples)\n");

    // -- 1. cold vs warm request latency -----------------------------------
    let t0 = Instant::now();
    let cold = exec(&core, &worker, &search_line(1_000, None, false));
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(cold[0].contains("\"table\":\"cold+compute\""), "first request is cold");
    let t0 = Instant::now();
    let warm = exec(&core, &worker, &search_line(1_000, None, false));
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(warm[0].contains("\"table\":\"warm\""), "second request is warm");
    println!("cold request (train+trace+build): {cold_ms:.0} ms");
    println!("warm request (1k samples):        {warm_ms:.2} ms\n");

    // -- 2. the in-process reference table (same artifacts, same bits) -----
    let mm = worker.rt.model(MODEL).expect("model");
    let sens = worker
        .pipe
        .sensitivity(&worker.rt, MODEL, 1, 0, {
            let mut t = fitq::coordinator::TraceOptions::default();
            t.batch = 8;
            t.min_iters = 2;
            t.max_iters = 2;
            t
        })
        .expect("sensitivity (cached by the cold request)");
    let table = FitTable::new(&sens.inputs, &mm.block_sizes(), mm.n_unquantized(), &PRECISIONS);
    let n_blocks = table.n_weight_blocks() + table.n_act_blocks();
    let n_prec = table.precisions().len();

    // equivalence gate: the served front == the in-process one-shot sweep
    let mut idx = Vec::new();
    let mut scores = Vec::with_capacity(SAMPLES as usize);
    for k in 0..SAMPLES {
        sample_indices_into(n_blocks, n_prec, 9, k, &mut idx);
        scores.push(table.score_size_indices(&idx));
    }
    let front = pareto_front_scores(&scores);
    let mut acc = ParetoAccumulator::new();
    acc.absorb_scores(0, &scores);
    assert_eq!(acc.indices(), front, "accumulator == sweep");
    let served = exec(&core, &worker, &search_line(SAMPLES, None, false));
    let served7 = exec(&core, &worker, &search_line(SAMPLES, Some(7), false));
    assert_eq!(invariant(&served[0]), invariant(&served7[0]), "shard invariance");
    let served_front = Json::parse(&served[0]).unwrap();
    let served_front = served_front
        .field("result")
        .unwrap()
        .arr_field("front")
        .unwrap()
        .iter()
        .map(|p| p.usize_field("index").unwrap())
        .collect::<Vec<_>>();
    assert_eq!(served_front, front, "served front == in-process sweep");

    // -- 3. throughput: in-process batch scorer (the floor to hold) --------
    let packed: Vec<PackedConfig> = {
        let mut out = Vec::with_capacity(SAMPLES as usize);
        let mut idx = Vec::new();
        for k in 0..SAMPLES {
            sample_indices_into(n_blocks, n_prec, 9, k, &mut idx);
            out.push(table.pack(&fitq::coordinator::service::sampled_config(&table, 9, k)));
        }
        out
    };
    let mut rows: Vec<(String, usize, f64)> = Vec::new();
    let mut buf = Vec::new();
    for jobs in [1usize, 0] {
        let r = bench(&format!("in-process score_batch_into jobs={jobs}"), 1, 10, || {
            table.score_batch_into(&packed, jobs, &mut buf);
            black_box(buf.len());
        });
        rows.push(("in_process_batch".into(), jobs, SAMPLES as f64 * 1e9 / r.mean_ns));
    }
    // the sampled path (draw + score, no PackedConfig) — what search shards run
    let r = bench("in-process sample+score serial", 1, 10, || {
        let mut acc = 0.0;
        for k in 0..SAMPLES {
            sample_indices_into(n_blocks, n_prec, 9, k, &mut idx);
            acc += table.score_size_indices(&idx).0;
        }
        black_box(acc);
    });
    rows.push(("in_process_sampled".into(), 1, SAMPLES as f64 * 1e9 / r.mean_ns));

    // -- 4. throughput: warm served requests (core, then real TCP) ---------
    for jobs in [1usize, 0] {
        let core_j = ServiceCore::new(
            BackendSpec::Native { threads: 1, zoo: Vec::new() },
            &dir,
            ServiceConfig { jobs, table_capacity: 8, shard_target: 16_384 },
        );
        let w_j = core_j.worker().expect("worker");
        exec(&core_j, &w_j, &search_line(1, None, false)); // warm the LRU
        let r = bench(&format!("served search (core) jobs={jobs}"), 1, 10, || {
            black_box(exec(&core_j, &w_j, &search_line(SAMPLES, None, false)).len());
        });
        rows.push(("served_core".into(), jobs, SAMPLES as f64 * 1e9 / r.mean_ns));
    }

    let listener = bind("127.0.0.1", 0).expect("bind");
    let addr = listener.local_addr().unwrap().to_string();
    {
        let core = core.clone();
        std::thread::spawn(move || serve_on(core, listener));
    }
    let line = search_line(SAMPLES, None, false);
    let r = bench("served search (tcp loopback)", 1, 10, || {
        let mut out: Vec<u8> = Vec::new();
        let err = query(&addr, std::slice::from_ref(&line), &mut out).expect("query");
        assert!(!err);
        black_box(out.len());
    });
    rows.push(("served_tcp".into(), 0, SAMPLES as f64 * 1e9 / r.mean_ns));

    // -- 5. streaming overhead ---------------------------------------------
    let r_oneshot = bench("one-shot front, 16 shards", 1, 10, || {
        black_box(exec(&core, &worker, &search_line(SAMPLES, Some(16), false)).len());
    });
    let r_stream = bench("streamed front, 16 shards", 1, 10, || {
        black_box(exec(&core, &worker, &search_line(SAMPLES, Some(16), true)).len());
    });
    let stream_overhead = r_stream.mean_ns / r_oneshot.mean_ns;
    println!("  -> streaming overhead: {stream_overhead:.3}x\n");

    let in_process = rows
        .iter()
        .filter(|(p, _, _)| p == "in_process_batch")
        .map(|&(_, _, cps)| cps)
        .fold(0.0f64, f64::max);
    let served = rows
        .iter()
        .filter(|(p, _, _)| p.starts_with("served"))
        .map(|&(_, _, cps)| cps)
        .fold(0.0f64, f64::max);
    let ratio = served / in_process;
    println!("  -> best served / best in-process throughput: {ratio:.3} (target >= 0.9)");

    // -- record the trajectory point ---------------------------------------
    let mut rows_json = String::new();
    for (i, (path, jobs, cps)) in rows.iter().enumerate() {
        if i > 0 {
            rows_json.push_str(",\n    ");
        }
        rows_json.push_str(&format!(
            "{{\"path\": \"{path}\", \"jobs\": {jobs}, \"configs_per_sec\": {cps:.1}}}"
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"search_service\",\n  \"status\": \"measured\",\n  \
         \"model\": \"{MODEL}\",\n  \"samples\": {SAMPLES},\n  \
         \"cold_ms\": {cold_ms:.1},\n  \"warm_ms\": {warm_ms:.3},\n  \
         \"throughput\": [\n    {rows_json}\n  ],\n  \
         \"served_vs_inprocess\": {ratio:.4},\n  \
         \"stream_overhead\": {stream_overhead:.4}\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_search_service.json");
    std::fs::write(path, &json).expect("write BENCH_search_service.json");
    println!("\nwrote {path}");
    std::fs::remove_dir_all(&dir).ok();
}
