"""EF-trace program correctness (paper §3.3, Prop. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.fisher import (
    make_act_ranges,
    make_ef_trace,
    make_ef_trace_persample,
    make_param_ranges,
    mean_loss,
)
from tests.conftest import synth_batch


def test_batch1_equals_persample(tiny_trained):
    """With B=1 the batch-gradient estimator IS the per-sample EF, exactly."""
    model, params, _ = tiny_trained
    rng = np.random.default_rng(1)
    x, y = synth_batch(rng, 1, model.input_shape, model.n_classes)
    w1, a1 = make_ef_trace(model)(params, x, y)
    w2, a2 = make_ef_trace_persample(model)(params, x, y)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4)


def test_persample_mean_identity(tiny_trained):
    """Per-sample EF over a batch == mean of singleton-batch EF values."""
    model, params, _ = tiny_trained
    rng = np.random.default_rng(2)
    x, y = synth_batch(rng, 4, model.input_shape, model.n_classes)
    ef1 = make_ef_trace(model)
    singles = [np.asarray(ef1(params, x[i : i + 1], y[i : i + 1])[0]) for i in range(4)]
    w_ps, _ = make_ef_trace_persample(model)(params, x, y)
    np.testing.assert_allclose(np.asarray(w_ps), np.mean(singles, axis=0), rtol=1e-4)


def test_ef_trace_shapes_and_nonneg(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(3)
    x, y = synth_batch(rng, 8, model.input_shape, model.n_classes)
    w_tr, a_tr = make_ef_trace(model)(params, x, y)
    assert w_tr.shape == (model.n_weight_blocks,)
    assert a_tr.shape == (model.n_act_blocks,)
    assert np.all(np.asarray(w_tr) >= 0) and np.all(np.asarray(a_tr) >= 0)


def test_ef_trace_rank_agreement_batch_vs_persample(tiny_trained):
    """Averaged over iterations, the batch estimator preserves block ranking."""
    from scipy import stats

    model, params, _ = tiny_trained
    rng = np.random.default_rng(4)
    b_est, ps_est = None, None
    n_iter = 30
    ef_b = jax.jit(make_ef_trace(model))
    ef_ps = jax.jit(make_ef_trace_persample(model))
    for _ in range(n_iter):
        x, y = synth_batch(rng, 8, model.input_shape, model.n_classes)
        wb, _ = ef_b(params, x, y)
        wp, _ = ef_ps(params, x, y)
        b_est = np.asarray(wb) if b_est is None else b_est + np.asarray(wb)
        ps_est = np.asarray(wp) if ps_est is None else ps_est + np.asarray(wp)
    rho = stats.spearmanr(b_est, ps_est).statistic
    assert rho == pytest.approx(1.0), (b_est, ps_est)


def test_ef_matches_analytic_gaussian_mean():
    """1-parameter sanity check against a hand-computed Fisher trace.

    Model: scalar 'network' p(y|x, t) = N(y; t, 1), loss = (y - t)^2 / 2.
    grad = (t - y); EF trace at t = E[(t - y)^2] -> 1 + (t - t*)^2 for
    y ~ N(t*, 1). We verify our estimator algebra (B * ||batch grad||^2
    averaged over draws) against the analytic value.
    """
    rng = np.random.default_rng(0)
    t, t_star = 1.5, 1.0
    b, iters = 8, 4000
    est = []
    for _ in range(iters):
        y = rng.normal(t_star, 1.0, size=b)
        g = np.mean(t - y)
        est.append(b * g * g)
    analytic = 1.0 + (t - t_star) ** 2 - (t - t_star) ** 2 * (1 - 1 / b) * 0
    # E[B ||gbar||^2] = B mu^2 + sigma^2 where mu = t - t*, sigma = 1
    expected = b * (t - t_star) ** 2 + 1.0
    assert np.mean(est) == pytest.approx(expected, rel=0.1)
    del analytic


def test_param_ranges(tiny_trained):
    model, params, _ = tiny_trained
    lo, hi = make_param_ranges(model)(params)
    assert lo.shape == hi.shape == (model.n_weight_blocks,)
    for i, name in enumerate(model.weight_block_names):
        t = np.asarray(model.layout.get(params, name))
        assert float(lo[i]) == pytest.approx(t.min())
        assert float(hi[i]) == pytest.approx(t.max())


def test_act_ranges_cover_observed(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(7)
    x, _ = synth_batch(rng, 16, model.input_shape, model.n_classes)
    lo, hi = make_act_ranges(model)(params, x)
    acts = []
    model.apply(params, x, collect=acts)
    for i, a in enumerate(acts):
        assert float(lo[i]) == pytest.approx(float(jnp.min(a)))
        assert float(hi[i]) == pytest.approx(float(jnp.max(a)))
    # ReLU outputs: lo must be >= 0
    assert np.all(np.asarray(lo) >= 0.0)


def test_mean_loss_decreases_under_training(tiny_trained):
    model, params, final_loss = tiny_trained
    # trained loss must beat the random-guess floor log(3) comfortably
    assert final_loss < 0.7 * np.log(3.0)
