"""Shared fixtures: tiny test models and a learnable synthetic dataset."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import CNNConfig, build_cnn
from compile import layers

# A deliberately tiny CNN so exact-Hessian cross-checks stay cheap.
TINY = CNNConfig("tiny", (8, 8, 1), (2,), n_classes=3, pool_after=(0,))
TINY_BN = CNNConfig("tiny_bn", (8, 8, 1), (2,), n_classes=3, pool_after=(0,), batch_norm=True)


@pytest.fixture(scope="session")
def tiny_model():
    return build_cnn(TINY)


@pytest.fixture(scope="session")
def tiny_bn_model():
    return build_cnn(TINY_BN)


def synth_batch(rng, n, shape, n_classes):
    """Class-conditional frequency patterns + noise (mirrors rust data/)."""
    h, w, c = shape
    ys = rng.integers(0, n_classes, size=n)
    hh, ww = np.meshgrid(np.arange(h) / h, np.arange(w) / w, indexing="ij")
    xs = np.zeros((n, h, w, c), np.float32)
    for i, y in enumerate(ys):
        cr = np.random.default_rng(1000 + int(y))
        fx, fy = cr.uniform(0.5, 3.0, 2)
        px, py = cr.uniform(0, 2 * np.pi, 2)
        for ch in range(c):
            pat = np.sin(2 * np.pi * fx * hh + px + 0.7 * ch) * np.cos(
                2 * np.pi * fy * ww + py
            )
            xs[i, :, :, ch] = pat
    xs += rng.normal(0, 0.3, xs.shape).astype(np.float32)
    return jnp.asarray(xs), jnp.asarray(ys.astype(np.int32))


@pytest.fixture(scope="session")
def tiny_trained(tiny_model):
    """Tiny model trained to (near) convergence on the synthetic task."""
    model = tiny_model
    params = layers.init_flat(model.layout, jnp.uint32(0))
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.float32(0.0)
    rng = np.random.default_rng(0)

    from compile.train import make_train_epoch

    epoch = jax.jit(make_train_epoch(model, 10))
    for _ in range(12):
        xs, ys = synth_batch(rng, 10 * 16, model.input_shape, model.n_classes)
        xs = xs.reshape(10, 16, *model.input_shape)
        ys = ys.reshape(10, 16)
        params, m, v, step, loss = epoch(params, m, v, step, xs, ys)
    return model, params, float(loss)
