"""Train / QAT / eval program tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.train import (
    make_eval,
    make_qat_epoch,
    make_qat_eval,
    make_train_epoch,
)
from tests.conftest import synth_batch


def _epoch_data(rng, model, k, b):
    xs, ys = synth_batch(rng, k * b, model.input_shape, model.n_classes)
    return xs.reshape(k, b, *model.input_shape), ys.reshape(k, b)


def _state(model):
    params = layers.init_flat(model.layout, jnp.uint32(0))
    return params, jnp.zeros_like(params), jnp.zeros_like(params), jnp.float32(0.0)


def test_train_epoch_reduces_loss(tiny_model):
    model = tiny_model
    params, m, v, step = _state(model)
    rng = np.random.default_rng(0)
    epoch = jax.jit(make_train_epoch(model, 10))
    losses = []
    for _ in range(8):
        xs, ys = _epoch_data(rng, model, 10, 16)
        params, m, v, step, loss = epoch(params, m, v, step, xs, ys)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses
    assert float(step) == 80.0


def test_train_epoch_deterministic(tiny_model):
    model = tiny_model
    rng = np.random.default_rng(1)
    xs, ys = _epoch_data(rng, model, 10, 16)
    epoch = jax.jit(make_train_epoch(model, 10))
    out1 = epoch(*_state(model), xs, ys)
    out2 = epoch(*_state(model), xs, ys)
    np.testing.assert_array_equal(np.asarray(out1[0]), np.asarray(out2[0]))


def _quant_args(model, bits):
    lw, la = model.n_weight_blocks, model.n_act_blocks
    return (
        jnp.full((lw,), float(bits)),
        jnp.full((la,), float(bits)),
        jnp.zeros((la,)),
        jnp.full((la,), 6.0),
    )


def test_qat_epoch_trains(tiny_trained):
    """QAT fine-tuning from an FP checkpoint keeps/improves quantized loss."""
    model, params, _ = tiny_trained
    m, v = jnp.zeros_like(params), jnp.zeros_like(params)
    step = jnp.float32(0.0)
    rng = np.random.default_rng(2)
    qat = jax.jit(make_qat_epoch(model, 10))
    bits = _quant_args(model, 4)
    losses = []
    for _ in range(6):
        xs, ys = _epoch_data(rng, model, 10, 16)
        params, m, v, step, loss = qat(params, m, v, step, xs, ys, *bits)
        losses.append(float(loss))
    assert losses[-1] <= losses[0] * 1.2, losses
    assert np.isfinite(losses).all()


def test_qat_high_bits_close_to_fp_loss(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(3)
    x, y = synth_batch(rng, 64, model.input_shape, model.n_classes)
    mask = jnp.ones((64,))
    ev = make_eval(model)
    qev = make_qat_eval(model)
    fp_loss = float(ev(params, x, y, mask)[0])
    q8_loss = float(qev(params, x, y, mask, *_quant_args(model, 8))[0])
    q2_loss = float(qev(params, x, y, mask, *_quant_args(model, 2))[0])
    assert abs(q8_loss - fp_loss) < 0.15 * abs(fp_loss) + 0.05
    assert q2_loss > q8_loss


def test_eval_mask(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(4)
    x, y = synth_batch(rng, 32, model.input_shape, model.n_classes)
    ev = make_eval(model)
    full = ev(params, x, y, jnp.ones((32,)))
    half_mask = jnp.concatenate([jnp.ones((16,)), jnp.zeros((16,))])
    half = ev(params, x, y, half_mask)
    first = ev(params, x[:16], y[:16], jnp.ones((16,)))
    assert float(half[2]) == 16.0 and float(full[2]) == 32.0
    assert float(half[1]) == pytest.approx(float(first[1]))
    assert float(half[0]) == pytest.approx(float(first[0]), rel=1e-5)


def test_eval_accuracy_reasonable(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(5)
    x, y = synth_batch(rng, 128, model.input_shape, model.n_classes)
    loss, correct, n = make_eval(model)(params, x, y, jnp.ones((128,)))
    acc = float(correct) / float(n)
    assert acc > 0.6, acc  # 3-class task, trained model


def test_unet_train_and_eval_smoke():
    from compile.unet import build_unet

    model = build_unet()
    params, m, v, step = _state(model)
    rng = np.random.default_rng(6)
    b = 4
    xs = jnp.asarray(rng.normal(size=(2, b, *model.input_shape)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, model.n_classes, size=(2, b, 32, 32)).astype(np.int32))
    epoch = make_train_epoch(model, 2)
    params, m, v, step, loss = epoch(params, m, v, step, xs, ys)
    assert np.isfinite(float(loss))
    out = make_eval(model)(params, xs[0], ys[0], jnp.ones((b,)))
    loss_sum, inter, union = out
    assert inter.shape == (model.n_classes,)
    assert np.all(np.asarray(inter) <= np.asarray(union) + 1e-6)
