"""Model construction, layout and apply-mode tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers
from compile.model import (
    CNN_CONFIGS,
    QuantInputs,
    build_cnn,
    get_model,
)
from compile.unet import build_unet
from tests.conftest import synth_batch


@pytest.mark.parametrize("name", list(CNN_CONFIGS))
def test_layout_is_contiguous(name):
    model = get_model(name)
    off = 0
    for s in model.layout.specs:
        assert s.offset == off
        off += s.size
    assert off == model.n_params


@pytest.mark.parametrize("name", ["cnn_mnist", "cnn_cifar_bn", "cnn_xl"])
def test_forward_shape(name):
    model = get_model(name)
    params = layers.init_flat(model.layout, jnp.uint32(1))
    x = jnp.zeros((5, *model.input_shape))
    logits = model.apply(params, x)
    assert logits.shape == (5, model.n_classes)


def test_unet_forward_shape():
    model = build_unet()
    params = layers.init_flat(model.layout, jnp.uint32(1))
    x = jnp.zeros((2, *model.input_shape))
    logits = model.apply(params, x)
    assert logits.shape == (2, 32, 32, model.n_classes)
    assert model.n_weight_blocks == 10
    assert model.n_act_blocks == 9


def test_init_deterministic_and_seed_sensitive(tiny_model):
    p0 = layers.init_flat(tiny_model.layout, jnp.uint32(7))
    p1 = layers.init_flat(tiny_model.layout, jnp.uint32(7))
    p2 = layers.init_flat(tiny_model.layout, jnp.uint32(8))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    assert not np.allclose(np.asarray(p0), np.asarray(p2))


def test_init_statistics(tiny_model):
    # gammas one, biases zero, weights he-scaled
    flat = layers.init_flat(tiny_model.layout, jnp.uint32(3))
    for s in tiny_model.layout.specs:
        t = np.asarray(tiny_model.layout.get(flat, s.name))
        if s.kind == "bias":
            np.testing.assert_array_equal(t, 0.0)
        elif s.kind == "conv_w":
            fan = s.shape[0] * s.shape[1] * s.shape[2]
            assert abs(t.std() - np.sqrt(2.0 / fan)) < 0.5 * np.sqrt(2.0 / fan)


def _quant_inputs(model, bits=8.0):
    lw, la = model.n_weight_blocks, model.n_act_blocks
    return QuantInputs(
        bits_w=jnp.full((lw,), bits),
        bits_a=jnp.full((la,), bits),
        act_lo=jnp.zeros((la,)),
        act_hi=jnp.full((la,), 6.0),
    )


def test_quant_8bit_close_to_fp(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(5)
    x, _ = synth_batch(rng, 16, model.input_shape, model.n_classes)
    fp = model.apply(params, x)
    q8 = model.apply(params, x, quant=_quant_inputs(model, 8.0))
    q2 = model.apply(params, x, quant=_quant_inputs(model, 2.0))
    err8 = float(jnp.max(jnp.abs(fp - q8)))
    err2 = float(jnp.max(jnp.abs(fp - q2)))
    assert err8 < err2, (err8, err2)
    assert err8 < 0.15 * float(jnp.max(jnp.abs(fp)))


def test_act_eps_zero_is_identity(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(6)
    x, _ = synth_batch(rng, 4, model.input_shape, model.n_classes)
    eps = [jnp.zeros((4, *s)) for s in model.act_shapes]
    np.testing.assert_allclose(
        np.asarray(model.apply(params, x)),
        np.asarray(model.apply(params, x, act_eps=eps)),
        atol=1e-6,
    )


def test_collect_shapes(tiny_trained):
    model, params, _ = tiny_trained
    x = jnp.zeros((3, *model.input_shape))
    acts = []
    model.apply(params, x, collect=acts)
    assert len(acts) == model.n_act_blocks
    for a, s in zip(acts, model.act_shapes):
        assert a.shape == (3, *s)


def test_bn_model_normalizes(tiny_bn_model):
    model = tiny_bn_model
    params = layers.init_flat(model.layout, jnp.uint32(2))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, *model.input_shape)).astype(np.float32))
    acts = []
    model.apply(params, x, collect=acts)
    # post-BN pre-ReLU would be zero-mean; post-ReLU mean is positive but bounded
    a = np.asarray(acts[0])
    assert 0.05 < a.mean() < 1.0


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 0.0, 0.0]])
    y = jnp.asarray([0, 2], jnp.int32)
    got = np.asarray(layers.softmax_xent(logits, y))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    np.testing.assert_allclose(got, [-np.log(p0), np.log(3.0)], rtol=1e-5)


def test_iou_counts_perfect_prediction():
    logits = jnp.zeros((1, 4, 4, 3)).at[..., 1].set(5.0)
    labels = jnp.ones((1, 4, 4), jnp.int32)
    inter, union = layers.iou_counts(logits, labels, jnp.ones((1,)), 3)
    assert float(inter[1]) == 16.0 and float(union[1]) == 16.0
    assert float(union[0]) == 0.0


def test_upsample2():
    x = jnp.arange(4.0).reshape(1, 2, 2, 1)
    up = layers.upsample2(x)
    assert up.shape == (1, 4, 4, 1)
    np.testing.assert_array_equal(
        np.asarray(up[0, :, :, 0]),
        [[0, 0, 1, 1], [0, 0, 1, 1], [2, 2, 3, 3], [2, 2, 3, 3]],
    )
