"""Hutchinson Hessian-trace program vs exact Hessian (paper §3.3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.fisher import mean_loss
from compile.hessian import make_hutchinson
from tests.conftest import synth_batch


def _exact_block_traces(model, params, x, y):
    H = jax.hessian(lambda f: mean_loss(model, f, x, y))(params)
    H = np.asarray(H)
    out = []
    for name in model.weight_block_names:
        s = model.layout.spec(name)
        sl = slice(s.offset, s.offset + s.size)
        out.append(np.trace(H[sl, sl]))
    return np.asarray(out)


def _rademacher(rng, n):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype(np.float32))


def test_hutchinson_unbiased_for_exact_trace(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(0)
    x, y = synth_batch(rng, 8, model.input_shape, model.n_classes)
    exact = _exact_block_traces(model, params, x, y)

    hutch = jax.jit(make_hutchinson(model))
    draws = []
    for _ in range(300):
        r = _rademacher(rng, model.n_params)
        draws.append(np.asarray(hutch(params, x, y, r)))
    est = np.mean(draws, axis=0)
    se = np.std(draws, axis=0) / np.sqrt(len(draws))
    # within 5 standard errors of the exact per-block traces
    assert np.all(np.abs(est - exact) < 5 * se + 1e-4), (est, exact, se)


def test_hutchinson_shape(tiny_trained):
    model, params, _ = tiny_trained
    rng = np.random.default_rng(1)
    x, y = synth_batch(rng, 4, model.input_shape, model.n_classes)
    r = _rademacher(rng, model.n_params)
    q = make_hutchinson(model)(params, x, y, r)
    assert q.shape == (model.n_weight_blocks,)


def test_hutchinson_variance_formula(tiny_trained):
    """Prop. 6: Var[r^T H r] = 2(||H||_F^2 - sum_i H_ii^2) for Rademacher r.

    Checked on the *total* (all-params) quadratic form against the exact
    Hessian of the batch loss, with the batch held fixed so r is the only
    randomness. The directional claim (Hutchinson variance >> EF variance on
    deep nets) is measured at scale by the Rust table1 experiment — on a
    119-parameter model the off-diagonal mass is too small for it to hold.
    """
    model, params, _ = tiny_trained
    rng = np.random.default_rng(2)
    x, y = synth_batch(rng, 8, model.input_shape, model.n_classes)
    H = np.asarray(jax.hessian(lambda f: mean_loss(model, f, x, y))(params))
    analytic = 2.0 * (np.sum(H * H) - np.sum(np.diag(H) ** 2))

    draws = []
    for _ in range(3000):
        r = np.asarray(rng.choice([-1.0, 1.0], size=model.n_params), np.float32)
        draws.append(r @ H @ r)
    emp = float(np.var(draws))
    assert emp == pytest.approx(analytic, rel=0.15), (emp, analytic)
