"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/block sizes; each kernel must match its
ref.py oracle to float tolerance. This is the core correctness signal for
the compiled artifacts — every L2 program routes its hot-spot through these
kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import (
    fake_quant,
    fake_quant_ref,
    noise_power_ref,
    quadform,
    quadform_ref,
    sqnorm,
    sqnorm_ref,
)

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _arr(rng, shape, dtype=np.float32, scale=2.0):
    return jnp.asarray(rng.normal(scale=scale, size=shape).astype(dtype))


# ---------------------------------------------------------------- sqnorm


@settings(**SETTINGS)
@given(
    b=st.integers(1, 17),
    n=st.integers(1, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_sqnorm_matches_ref(b, n, seed):
    rng = np.random.default_rng(seed)
    g = _arr(rng, (b, n))
    got = sqnorm(g, block_b=4, block_n=128)
    want = sqnorm_ref(g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    block_b=st.sampled_from([1, 2, 8]),
    block_n=st.sampled_from([32, 128, 2048]),
)
def test_sqnorm_block_shape_invariance(block_b, block_n):
    rng = np.random.default_rng(7)
    g = _arr(rng, (11, 301))
    got = sqnorm(g, block_b=block_b, block_n=block_n)
    np.testing.assert_allclose(got, sqnorm_ref(g), rtol=1e-5, atol=1e-6)


def test_sqnorm_bf16_input():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(4, 200)), jnp.bfloat16)
    got = sqnorm(g, block_n=128)
    np.testing.assert_allclose(got, sqnorm_ref(g), rtol=2e-2)


def test_sqnorm_zero_input():
    out = sqnorm(jnp.zeros((3, 50)), block_n=64)
    assert out.shape == (3,)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(3))


def test_sqnorm_rejects_non_2d():
    with pytest.raises(AssertionError):
        sqnorm(jnp.zeros((2, 3, 4)))


# -------------------------------------------------------------- quadform


@settings(**SETTINGS)
@given(n=st.integers(1, 10_000), seed=st.integers(0, 2**31 - 1))
def test_quadform_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    r, v = _arr(rng, (n,)), _arr(rng, (n,))
    got = quadform(r, v, block_n=512)
    want = quadform_ref(r, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_quadform_self_is_sqnorm():
    rng = np.random.default_rng(1)
    r = _arr(rng, (4096,))
    got = quadform(r, r)
    want = sqnorm_ref(r[None, :])[0]
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_quadform_rademacher_identity():
    # r in {-1, 1}^n: <r, r> = n exactly.
    rng = np.random.default_rng(2)
    r = jnp.asarray(rng.choice([-1.0, 1.0], size=5000).astype(np.float32))
    assert float(quadform(r, r)) == pytest.approx(5000.0)


# ------------------------------------------------------------ fake_quant


@settings(**SETTINGS)
@given(
    n=st.integers(1, 5000),
    bits=st.sampled_from([2.0, 3.0, 4.0, 6.0, 8.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n,))
    lo, hi = float(np.min(np.asarray(x))), float(np.max(np.asarray(x)))
    got = fake_quant(x, lo, hi, bits, block_n=256)
    want = fake_quant_ref(x, lo, hi, bits)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(bits=st.sampled_from([3.0, 4.0, 8.0]), seed=st.integers(0, 1000))
def test_fake_quant_error_bounded_by_half_step(bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (777,))
    lo = float(np.min(np.asarray(x)))
    hi = float(np.max(np.asarray(x)))
    q = np.asarray(fake_quant(x, lo, hi, bits, block_n=256))
    delta = (hi - lo) / (2.0**bits - 1.0)
    assert np.max(np.abs(q - np.asarray(x))) <= delta / 2 + 1e-5


def test_fake_quant_idempotent():
    rng = np.random.default_rng(9)
    x = _arr(rng, (300,))
    lo, hi = -3.0, 3.0
    q1 = fake_quant(x, lo, hi, 4.0)
    q2 = fake_quant(q1, lo, hi, 4.0)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


def test_fake_quant_degenerate_range_passthrough():
    rng = np.random.default_rng(4)
    x = _arr(rng, (100,))
    np.testing.assert_array_equal(
        np.asarray(fake_quant(x, 0.0, 0.0, 8.0)), np.asarray(x)
    )


def test_fake_quant_preserves_shape_and_dtype():
    x = jnp.ones((3, 5, 7), jnp.float32)
    out = fake_quant(x, 0.0, 2.0, 8.0)
    assert out.shape == (3, 5, 7) and out.dtype == jnp.float32


def test_fake_quant_endpoints_are_fixed_points():
    x = jnp.asarray([-1.5, 1.5], jnp.float32)
    out = np.asarray(fake_quant(x, -1.5, 1.5, 3.0))
    np.testing.assert_allclose(out, [-1.5, 1.5], atol=1e-6)


def test_fake_quant_level_count():
    # 2-bit quantization of a dense line hits exactly 4 distinct levels.
    x = jnp.linspace(-1.0, 1.0, 1001)
    out = np.asarray(fake_quant(x, -1.0, 1.0, 2.0))
    assert len(np.unique(np.round(out, 6))) == 4


def test_fake_quant_traced_bits():
    # bits as a traced runtime value — the MPQ-config-as-input contract.
    rng = np.random.default_rng(5)
    x = _arr(rng, (512,))

    f = jax.jit(lambda x, b: fake_quant(x, -2.0, 2.0, b))
    for b in [3.0, 4.0, 8.0]:
        np.testing.assert_allclose(
            np.asarray(f(x, jnp.float32(b))),
            np.asarray(fake_quant_ref(x, -2.0, 2.0, b)),
            rtol=1e-5,
            atol=1e-6,
        )


# ----------------------------------------------------------- noise model


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2.0, 3.0, 4.0, 6.0, 8.0]),
    lo=st.floats(-10, 0),
    width=st.floats(0.01, 20),
)
def test_noise_power_matches_empirical(bits, lo, width):
    # E[(Q(x) - x)^2] over uniform x should approach delta^2/12.
    hi = lo + width
    x = jnp.asarray(
        np.random.default_rng(0).uniform(lo, hi, size=200_000).astype(np.float32)
    )
    q = np.asarray(fake_quant_ref(x, lo, hi, bits))
    emp = float(np.mean((q - np.asarray(x)) ** 2))
    model = float(noise_power_ref(lo, hi, bits))
    assert emp == pytest.approx(model, rel=0.05)


# ---------------------------------------------------------- auto blocking


def test_auto_block_properties():
    from compile.kernels.sqnorm import auto_block

    for n in [1, 5, 127, 128, 129, 4096, 100_000, 2_000_001]:
        b = auto_block(n, 128)
        assert b % 128 == 0, (n, b)
        steps = -(-n // b)
        assert steps <= 4, (n, b, steps)
    # covering block for tiny inputs is one aligned tile
    assert auto_block(1, 128) == 128


def test_sqnorm_auto_blocks_match_explicit():
    rng = np.random.default_rng(11)
    g = _arr(rng, (9, 7000))
    auto = sqnorm(g)  # auto block sizes
    pinned = sqnorm(g, block_b=8, block_n=2048)  # TPU-style schedule
    np.testing.assert_allclose(np.asarray(auto), np.asarray(pinned), rtol=1e-5)


def test_quadform_auto_blocks_match_explicit():
    rng = np.random.default_rng(12)
    r, v = _arr(rng, (10_001,)), _arr(rng, (10_001,))
    np.testing.assert_allclose(
        float(quadform(r, v)), float(quadform(r, v, block_n=512)), rtol=1e-4
    )
