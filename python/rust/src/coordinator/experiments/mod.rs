// experiments (in progress)
