"""Pallas kernel: per-sample squared-gradient-norm reduction.

This is the compute hot-spot of the empirical-Fisher trace estimator
(paper §3.3, Prop. 5): for a batch of per-sample gradients g in R^{B x N},
produce out[i] = ||g[i]||^2. The EF trace is then the mean over samples.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (B, N) plane is tiled
into VMEM-resident (BLOCK_B, BLOCK_N) blocks via BlockSpec; the grid walks
the N (chunk) dimension innermost, accumulating partial row sums directly in
the (BLOCK_B,)-shaped output block, which Pallas keeps resident in VMEM
across the inner grid dimension. The op is a pure VPU reduction (no second
operand for the MXU), so it is memory-bound; block sizes are chosen to keep
the working set well under VMEM while giving full (8, 128) lanes.

interpret=True everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls; the interpret path lowers to plain HLO that the Rust runtime
runs. The structure (BlockSpec schedule) is still the TPU design.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. BLOCK_N is a multiple of the 128-lane dimension;
# BLOCK_B a multiple of the 8-sublane dimension. VMEM working set per step:
# BLOCK_B * BLOCK_N * 4 bytes = 8 * 2048 * 4 = 64 KiB (x2 for double
# buffering) — far under the ~16 MiB VMEM budget, leaving room for the
# surrounding model's own tiles.
BLOCK_B = 8
BLOCK_N = 2048

# interpret=True executes the grid as an XLA while loop whose per-step
# dynamic-slice/update overhead dominates on CPU (~ms per step); real TPU
# pipelining makes many small steps free. CPU adaptation (EXPERIMENTS.md
# §Perf L1): auto-size blocks so the grid stays at <= MAX_GRID_STEPS while
# respecting the (8, 128) tile alignment the TPU layout wants.
MAX_GRID_STEPS = 4


def auto_block(n: int, align: int, max_steps: int = MAX_GRID_STEPS) -> int:
    """Smallest `align`-multiple block covering n in <= max_steps steps."""
    target = -(-n // max_steps)  # ceil div
    return -(-target // align) * align


def _sqnorm_kernel(x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(x * x, axis=1)


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def sqnorm(g, *, block_b: int | None = None, block_n: int | None = None):
    """Per-sample squared l2 norms of a (B, N) block of gradients.

    Zero-pads both axes to tile multiples (zero rows/cols contribute zero
    to the sums) and slices the result back to (B,). Block sizes default to
    the interpret-mode auto sizing (see auto_block); pass explicit sizes to
    pin a TPU-style schedule (the tests sweep small blocks).
    """
    assert g.ndim == 2, f"sqnorm expects (B, N), got {g.shape}"
    if block_b is None:
        block_b = min(BLOCK_B, max(1, g.shape[0]))
    if block_n is None:
        block_n = auto_block(g.shape[1], 128)
    b, _ = g.shape
    gp = _pad_to(_pad_to(g, 1, block_n), 0, block_b)
    bp, np_ = gp.shape
    grid = (bp // block_b, np_ // block_n)
    out = pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=True,
    )(gp)
    return out[:b]
