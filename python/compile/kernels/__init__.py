"""L1 Pallas kernels for the FIT metric's compute hot-spots.

Three kernels, each with a pure-jnp oracle in ref.py:

- sqnorm:     per-sample ||grad||^2 — the EF-trace estimator core.
- quadform:   blocked <r, Hr> — the Hutchinson quadratic form.
- fake_quant: uniform min-max quantize-dequantize with runtime bit widths —
              the QAT forward-pass hot-spot.

All pallas_calls use interpret=True (CPU PJRT cannot run Mosaic
custom-calls); the BlockSpec schedules are still written for the TPU memory
hierarchy (DESIGN.md section Hardware-Adaptation).
"""

from .fake_quant import fake_quant
from .quadform import quadform
from .ref import fake_quant_ref, noise_power_ref, quadform_ref, sqnorm_ref
from .sqnorm import sqnorm

__all__ = [
    "fake_quant",
    "fake_quant_ref",
    "noise_power_ref",
    "quadform",
    "quadform_ref",
    "sqnorm",
    "sqnorm_ref",
]
