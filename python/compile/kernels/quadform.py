"""Pallas kernel: blocked quadratic-form dot product.

Computes <r, v> for flat vectors — the Hutchinson estimator's quadratic form
r^T (H r) once the HVP v = H r has been formed by the L2 autodiff program
(paper §3.3). A single grid dimension walks BLOCK_N-sized VMEM tiles of both
streams; the scalar partial sum accumulates in the output block, which stays
VMEM-resident across the whole grid.

Memory-bound (two input streams, one fused multiply-add reduction);
BLOCK_N = 4096 keeps the per-step working set at 2 * 16 KiB with headroom
for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 4096


def _quadform_kernel(r_ref, v_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r = r_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(r * v)[None]


def _pad1(x, multiple):
    rem = (-x.shape[0]) % multiple
    return jnp.pad(x, (0, rem)) if rem else x


@functools.partial(jax.jit, static_argnames=("block_n",))
def quadform(r, v, *, block_n: int | None = None):
    """<r, v> over flat (N,) vectors, zero-padded to the tile multiple.

    block_n defaults to interpret-mode auto sizing (few grid steps on CPU;
    see sqnorm.auto_block) — pass an explicit size to pin a TPU schedule.
    """
    assert r.ndim == 1 and r.shape == v.shape, (r.shape, v.shape)
    if block_n is None:
        from .sqnorm import auto_block

        block_n = auto_block(r.shape[0], 128)
    rp, vp = _pad1(r, block_n), _pad1(v, block_n)
    grid = (rp.shape[0] // block_n,)
    out = pl.pallas_call(
        _quadform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(rp, vp)
    return out[0]
