"""Pallas kernel: uniform min-max fake quantization (quantize-dequantize).

The QAT forward pass (paper Appendix A) replaces every quantizable tensor x
with Q(x) = round((clip(x) - lo)/delta) * delta + lo, delta = (hi - lo) /
(2^b - 1). `bits` is a RUNTIME scalar input — delta is computed inside the
kernel from exp2(bits) — so one compiled executable serves every mixed-
precision configuration (DESIGN.md key decision #3).

TPU mapping: pure elementwise VPU work on (8, 128)-aligned tiles; scale,
round, clamp and dequantize are fused in a single VMEM pass so the tensor
makes exactly one HBM round trip. The three scalars ride along as (1,)
blocks mapped to element 0 for every grid step (SMEM-resident on real TPU).

Degenerate ranges (hi <= lo, e.g. an all-zero bias) pass through unchanged,
matching ref.fake_quant_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 4096


def _fq_kernel(x_ref, lo_ref, hi_ref, bits_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    lo = lo_ref[0]
    hi = hi_ref[0]
    levels = jnp.exp2(bits_ref[0]) - 1.0
    ok = (hi > lo) & (levels >= 1.0)
    delta = jnp.where(ok, (hi - lo) / jnp.maximum(levels, 1.0), 1.0)
    q = jnp.round((jnp.clip(x, lo, hi) - lo) / delta)
    o_ref[...] = jnp.where(ok, q * delta + lo, x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def fake_quant(x, lo, hi, bits, *, block_n: int | None = None):
    """Quantize-dequantize a tensor of any shape with runtime bit width.

    x: any shape/float dtype; lo, hi, bits: scalars (may be traced).
    Returns the same shape/dtype as x. block_n defaults to interpret-mode
    auto sizing (see sqnorm.auto_block).
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    n = flat.shape[0]
    if block_n is None:
        from .sqnorm import auto_block

        block_n = auto_block(n, 128)
    rem = (-n) % block_n
    if rem:
        flat = jnp.pad(flat, (0, rem))
    scal = lambda s: jnp.asarray(s, jnp.float32).reshape(1)
    grid = (flat.shape[0] // block_n,)
    out = pl.pallas_call(
        _fq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, dtype),
        interpret=True,
    )(flat, scal(lo), scal(hi), scal(bits))
    return out[:n].reshape(shape)
