"""Pure-jnp oracles for the L1 Pallas kernels.

These definitions are the correctness contract: every Pallas kernel in this
package must match its oracle to float tolerance across the shape/dtype
sweep in python/tests/. They are also used directly by the L2 programs when
a shape falls below the kernel's minimum tile (dispatch in __init__.py).
"""

import jax.numpy as jnp


def sqnorm_ref(g):
    """Per-sample squared l2 norm.

    g: (B, N) per-sample flattened gradient block.
    returns: (B,) with out[i] = sum_j g[i, j]^2.

    This is the inner loop of the empirical-Fisher trace estimator
    (paper Prop. 5): Tr[I_hat] = (1/N) sum_i ||grad f(z_i)||^2.
    """
    g = g.astype(jnp.float32)
    return jnp.sum(g * g, axis=-1)


def quadform_ref(r, v):
    """Blocked dot product <r, v>.

    Used as the Hutchinson quadratic form r^T (H r) given an HVP result v.
    r, v: (N,). returns: () scalar.
    """
    return jnp.vdot(r.astype(jnp.float32), v.astype(jnp.float32))


def fake_quant_ref(x, lo, hi, bits):
    """Uniform min-max quantize-dequantize (paper Appendix E).

    Q(x) = round((x - lo) / delta) * delta + lo,  delta = (hi - lo)/(2^b - 1)
    Values are clipped into [lo, hi]. Degenerate ranges (hi <= lo) pass x
    through unchanged. `bits` may be a runtime (traced) float scalar — this
    is what lets one compiled QAT executable serve every MPQ config.
    """
    x32 = x.astype(jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    bits = jnp.asarray(bits, jnp.float32)
    levels = jnp.exp2(bits) - 1.0
    ok = (hi > lo) & (levels >= 1.0)
    delta = jnp.where(ok, (hi - lo) / jnp.maximum(levels, 1.0), 1.0)
    q = jnp.round((jnp.clip(x32, lo, hi) - lo) / delta)
    deq = q * delta + lo
    return jnp.where(ok, deq, x32).astype(x.dtype)


def noise_power_ref(lo, hi, bits):
    """Quantization noise power E[dtheta^2] = delta^2 / 12 (Appendix E)."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    levels = jnp.exp2(jnp.asarray(bits, jnp.float32)) - 1.0
    ok = (hi > lo) & (levels >= 1.0)
    delta = jnp.where(ok, (hi - lo) / jnp.maximum(levels, 1.0), 0.0)
    return delta * delta / 12.0
