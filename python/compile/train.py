"""Training, QAT and evaluation programs (paper Appendix A/D).

Entry points are built per model and lowered by aot.py. Training runs K
Adam steps per PJRT dispatch under lax.scan (DESIGN.md key decision #4) —
the Rust coordinator supplies (K, B, ...) microbatch stacks and carries the
flat (params, m, v, step) state between calls.

QAT uses the shared `apply(quant=...)` path: per-block min-max weight
fake-quant with STE, calibrated activation ranges passed as inputs, and
runtime per-block bit widths so one compiled executable serves every MPQ
configuration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .fisher import mean_loss, softmax_per_example
from .model import Model, QuantInputs

ADAM = layers.AdamConfig(lr=1e-2)
QAT_ADAM = layers.AdamConfig(lr=1e-3)  # paper: lr reduction of 0.1 for QAT
# deeper/wider models need a cooler lr to avoid softmax collapse on the
# synthetic task (observed on cnn_xl at 1e-2: loss pinned at ln 10)
ADAM_LR_OVERRIDES = {"cnn_xl": 2e-3, "cnn_l": 5e-3}


def adam_for(model: Model) -> layers.AdamConfig:
    lr = ADAM_LR_OVERRIDES.get(model.name, ADAM.lr)
    return layers.AdamConfig(lr=lr)


def _loss(model: Model, flat, x, y, quant=None):
    return mean_loss(model, flat, x, y, quant=quant)


def make_train_epoch(model: Model, k: int):
    """(params, m, v, step, xs (K,B,...), ys (K,B,...)) -> (params, m, v, step, mean_loss)."""

    def step_fn(carry, batch):
        params, m, v, step = carry
        x, y = batch
        loss, g = jax.value_and_grad(_loss, argnums=1)(model, params, x, y)
        step = step + 1.0
        params, m, v = layers.adam_update(adam_for(model), g, params, m, v, step)
        return (params, m, v, step), loss

    def train_epoch(params, m, v, step, xs, ys):
        (params, m, v, step), losses = jax.lax.scan(
            step_fn, (params, m, v, step), (xs, ys), length=k
        )
        return params, m, v, step, jnp.mean(losses)

    return train_epoch


def make_qat_epoch(model: Model, k: int):
    """Train epoch with fake-quantized forward (STE backward)."""

    def qat_epoch(params, m, v, step, xs, ys, bits_w, bits_a, act_lo, act_hi):
        quant = QuantInputs(bits_w, bits_a, act_lo, act_hi)

        def step_fn(carry, batch):
            params, m, v, step = carry
            x, y = batch
            loss, g = jax.value_and_grad(_loss, argnums=1)(model, params, x, y, quant)
            step = step + 1.0
            params, m, v = layers.adam_update(QAT_ADAM, g, params, m, v, step)
            return (params, m, v, step), loss

        (params, m, v, step), losses = jax.lax.scan(
            step_fn, (params, m, v, step), (xs, ys), length=k
        )
        return params, m, v, step, jnp.mean(losses)

    return qat_epoch


def _eval_outputs(model: Model, logits, y, mask):
    per = softmax_per_example(model, logits, y)
    loss_sum = jnp.sum(per * mask)
    if model.task == "segment":
        inter, union = layers.iou_counts(logits, y, mask, model.n_classes)
        return loss_sum, inter, union
    correct = layers.accuracy_counts(logits, y, mask)
    return loss_sum, correct, jnp.sum(mask)


def make_eval(model: Model):
    """(params, x, y, mask) -> classify: (loss_sum, correct, n) / segment: (loss_sum, inter(C,), union(C,))."""

    def eval_batch(params, x, y, mask):
        logits = model.apply(params, x)
        return _eval_outputs(model, logits, y, mask)

    return eval_batch


def make_qat_eval(model: Model):
    """Quantized-model evaluation — same outputs as make_eval."""

    def qat_eval(params, x, y, mask, bits_w, bits_a, act_lo, act_hi):
        quant = QuantInputs(bits_w, bits_a, act_lo, act_hi)
        logits = model.apply(params, x, quant=quant)
        return _eval_outputs(model, logits, y, mask)

    return qat_eval


def make_predict(model: Model):
    def predict(params, x):
        return model.apply(params, x)

    return predict
