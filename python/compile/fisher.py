"""Empirical-Fisher trace programs (paper §3.3, Prop. 5).

One Monte-Carlo "iteration" of the EF trace estimator processes a batch:

    s_l = B * || grad_{theta_l} (1/B) sum_i f(z_i) ||^2        (weights)
    t_l = B * || grad_{a_l}     (1/B) sum_i f(z_i) ||^2        (activations)

i.e. the squared batch-gradient norm per quantizable block, debiased by the
batch size. Near a minimum (||E[g]|| -> 0) the expectation of s_l converges
to Tr(I_hat(theta_l)); this is the single-backward estimator whose cost and
variance the paper's Table 1/3/4 measure against the Hutchinson Hessian
estimator. The exact per-sample form (vmap(grad), `ef_trace_persample`) is
kept for validation — python/tests/test_fisher.py checks the two agree on
converged models.

Activation gradients come from the eps-trick: every activation site adds a
zero tensor eps_l; grad w.r.t. eps_l equals grad w.r.t. the activation
(paper §3.2.1 "derivatives w.r.t. activations").

The block reductions route through the L1 `sqnorm` Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import sqnorm
from .model import Model


def mean_loss(model: Model, flat, x, y, act_eps=None, quant=None):
    """Mean cross-entropy over the batch (and pixels, for segmentation)."""
    logits = model.apply(flat, x, quant=quant, act_eps=act_eps)
    per = softmax_per_example(model, logits, y)
    return jnp.mean(per)


def softmax_per_example(model: Model, logits, y):
    from . import layers

    if model.task == "segment":
        # (B, H, W) pixel losses -> per-sample mean
        return jnp.mean(layers.softmax_xent(logits, y), axis=(1, 2))
    return layers.softmax_xent(logits, y)


def _zero_eps(model: Model, batch: int):
    return [jnp.zeros((batch, *s), jnp.float32) for s in model.act_shapes]


def _block_sqnorms(model: Model, g_flat: jnp.ndarray) -> jnp.ndarray:
    """Per-weight-block squared norms of a flat gradient, via the L1 kernel."""
    rows = []
    for name in model.weight_block_names:
        slab = model.layout.slab(g_flat, name)
        rows.append(sqnorm(slab[None, :])[0])
    return jnp.stack(rows)


def make_ef_trace(model: Model):
    """(flat, x, y) -> (w_tr (Lw,), a_tr (La,)) — one estimator iteration."""

    def ef_trace(flat, x, y):
        b = x.shape[0]
        eps = _zero_eps(model, b)
        g_flat, g_eps = jax.grad(mean_loss, argnums=(1, 4))(model, flat, x, y, eps)
        w_tr = _block_sqnorms(model, g_flat) * b
        a_tr = jnp.stack(
            [sqnorm(g.reshape(1, -1))[0] for g in g_eps]
        ) * b
        return w_tr, a_tr

    return ef_trace


def make_ef_trace_persample(model: Model):
    """Exact per-sample EF trace: mean_i ||grad f(z_i)||^2 per block.

    Build-time validation oracle for `make_ef_trace` (not exported to the
    Rust runtime — its cost is B backward passes).
    """

    def one(flat, x1, y1):
        eps = _zero_eps(model, 1)
        g_flat, g_eps = jax.grad(mean_loss, argnums=(1, 4))(
            model, flat, x1[None], y1[None], eps
        )
        w = _block_sqnorms(model, g_flat)
        a = jnp.stack([sqnorm(g.reshape(1, -1))[0] for g in g_eps])
        return w, a

    def ef_trace_ps(flat, x, y):
        w, a = jax.vmap(one, in_axes=(None, 0, 0))(flat, x, y)
        return jnp.mean(w, axis=0), jnp.mean(a, axis=0)

    return ef_trace_ps


def make_param_ranges(model: Model):
    """(flat,) -> (lo (Lw,), hi (Lw,)) min-max weight ranges per block."""

    def param_ranges(flat):
        lo, hi = [], []
        for name in model.weight_block_names:
            slab = model.layout.slab(flat, name)
            lo.append(jnp.min(slab))
            hi.append(jnp.max(slab))
        return jnp.stack(lo), jnp.stack(hi)

    return param_ranges


def make_act_ranges(model: Model):
    """(flat, x) -> (lo (La,), hi (La,)) calibrated activation ranges."""

    def act_ranges(flat, x):
        acts: list[jnp.ndarray] = []
        model.apply(flat, x, collect=acts)
        lo = jnp.stack([jnp.min(a) for a in acts])
        hi = jnp.stack([jnp.max(a) for a in acts])
        return lo, hi

    return act_ranges
