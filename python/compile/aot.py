"""AOT compiler: lower every L2 entry point to HLO text + manifest.json.

The interchange format is HLO *text*, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published `xla` 0.1.6 crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in artifacts/<model>/<entry>.hlo.txt; artifacts/manifest.json
describes every model (flat parameter layout, quantizable blocks) and every
entry point (input/output shapes and dtypes) so the Rust runtime stays
completely model-agnostic.

Usage: cd python && python -m compile.aot --out ../artifacts [--models a,b]
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import fisher, hessian, layers, train
from .model import SCALE_MODELS, STUDY_MODELS, Model, get_model

TRAIN_K = 10  # microbatch steps per train/qat dispatch (lax.scan)
TRAIN_B = 32
EVAL_B = 256
CALIB_B = 128
PREDICT_B = 32
STUDY_TRACE_BS = (32,)
SCALE_TRACE_BS = (4, 8, 16, 32)

# unet is conv-heavy; smaller batches keep CPU-PJRT dispatches sub-second.
UNET_TRAIN_B = 8
UNET_EVAL_B = 32
UNET_CALIB_B = 32


def _dt(s: str):
    return {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}[s]


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), _dt(dtype))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io_manifest(specs, names):
    assert len(specs) == len(names), (len(specs), names)
    out = []
    for s, n in zip(specs, names):
        dt = {jnp.float32: "f32", jnp.int32: "i32", jnp.uint32: "u32"}[
            jnp.dtype(s.dtype).type
        ]
        out.append({"name": n, "shape": list(s.shape), "dtype": dt})
    return out


class EntrySet:
    """Collects (fn, input specs, io names) per entry for one model."""

    def __init__(self, model: Model):
        self.model = model
        self.entries: dict[str, tuple] = {}

    def add(self, name, fn, in_specs, in_names, out_names):
        self.entries[name] = (fn, in_specs, in_names, out_names)


def build_entries(model: Model) -> EntrySet:
    m = model
    es = EntrySet(m)
    n, hwc = m.n_params, m.input_shape
    lw, la = m.n_weight_blocks, m.n_act_blocks
    is_unet = m.name == "unet"
    tb = UNET_TRAIN_B if is_unet else TRAIN_B
    eb = UNET_EVAL_B if is_unet else EVAL_B
    cb = UNET_CALIB_B if is_unet else CALIB_B
    y_shape = (lambda b: (b, hwc[0], hwc[1])) if m.task == "segment" else (lambda b: (b,))

    es.add(
        "init",
        lambda seed: (layers.init_flat(m.layout, seed),),
        [spec((), "u32")],
        ["seed"],
        ["params"],
    )

    state_specs = [spec((n,)), spec((n,)), spec((n,)), spec(())]
    state_names = ["params", "m", "v", "step"]
    batch_specs = [spec((TRAIN_K, tb, *hwc)), spec((TRAIN_K, *y_shape(tb)), "i32")]
    out_state = ["params", "m", "v", "step", "loss"]

    train_epoch = train.make_train_epoch(m, TRAIN_K)
    es.add(
        "train_epoch",
        train_epoch,
        state_specs + batch_specs,
        state_names + ["xs", "ys"],
        out_state,
    )

    if m.name == "cnn_mnist":
        # K=1 variant kept solely for the §Perf scan-amortization study
        # (EXPERIMENTS.md): same program, one microbatch per dispatch.
        es.add(
            "train_step",
            train.make_train_epoch(m, 1),
            state_specs + [spec((1, tb, *hwc)), spec((1, *y_shape(tb)), "i32")],
            state_names + ["xs", "ys"],
            out_state,
        )

    quant_specs = [spec((lw,)), spec((la,)), spec((la,)), spec((la,))]
    quant_names = ["bits_w", "bits_a", "act_lo", "act_hi"]

    if m.name in STUDY_MODELS or is_unet:
        qat_epoch = train.make_qat_epoch(m, TRAIN_K)
        es.add(
            "qat_epoch",
            qat_epoch,
            state_specs + batch_specs + quant_specs,
            state_names + ["xs", "ys"] + quant_names,
            out_state,
        )

        eval_specs = [spec((n,)), spec((eb, *hwc)), spec(y_shape(eb), "i32"), spec((eb,))]
        eval_names = ["params", "x", "y", "mask"]
        eval_out = (
            ["loss_sum", "inter", "union"]
            if m.task == "segment"
            else ["loss_sum", "correct", "n"]
        )
        es.add("eval", train.make_eval(m), eval_specs, eval_names, eval_out)
        es.add(
            "qat_eval",
            train.make_qat_eval(m),
            eval_specs + quant_specs,
            eval_names + quant_names,
            eval_out,
        )
        es.add(
            "predict",
            train.make_predict(m),
            [spec((n,)), spec((PREDICT_B, *hwc))],
            ["params", "x"],
            ["logits"],
        )

    es.add(
        "param_ranges",
        fisher.make_param_ranges(m),
        [spec((n,))],
        ["params"],
        ["lo", "hi"],
    )
    es.add(
        "act_ranges",
        fisher.make_act_ranges(m),
        [spec((n,)), spec((cb, *hwc))],
        ["params", "x"],
        ["lo", "hi"],
    )

    trace_bs = SCALE_TRACE_BS if m.name in SCALE_MODELS else STUDY_TRACE_BS
    ef = fisher.make_ef_trace(m)
    for b in trace_bs:
        es.add(
            f"ef_trace_bs{b}",
            ef,
            [spec((n,)), spec((b, *hwc)), spec(y_shape(b), "i32")],
            ["params", "x", "y"],
            ["w_tr", "a_tr"],
        )
    if m.name in SCALE_MODELS:
        hutch = hessian.make_hutchinson(m)
        for b in SCALE_TRACE_BS:
            es.add(
                f"hutch_bs{b}",
                hutch,
                [spec((n,)), spec((b, *hwc)), spec(y_shape(b), "i32"), spec((n,))],
                ["params", "x", "y", "r"],
                ["quad"],
            )
    return es


def model_manifest(model: Model, entry_manifests: dict) -> dict:
    layout = model.layout
    blocks = []
    for i, name in enumerate(model.weight_block_names):
        s = layout.spec(name)
        blocks.append(
            {
                "index": i,
                "name": name,
                "offset": s.offset,
                "size": s.size,
                "shape": list(s.shape),
            }
        )
    is_unet = model.name == "unet"
    return {
        "n_params": layout.n_params,
        "input_shape": list(model.input_shape),
        "n_classes": model.n_classes,
        "task": model.task,
        "train_k": TRAIN_K,
        "train_b": UNET_TRAIN_B if is_unet else TRAIN_B,
        "eval_b": UNET_EVAL_B if is_unet else EVAL_B,
        "calib_b": UNET_CALIB_B if is_unet else CALIB_B,
        "predict_b": PREDICT_B,
        "trace_bs": list(SCALE_TRACE_BS if model.name in SCALE_MODELS else STUDY_TRACE_BS),
        "weight_blocks": blocks,
        "act_blocks": [
            {"index": i, "shape": list(s), "size": math.prod(s)}
            for i, s in enumerate(model.act_shapes)
        ],
        "tensors": layout.to_manifest(),
        "entries": entry_manifests,
    }


ALL_MODELS = list(STUDY_MODELS) + list(SCALE_MODELS) + ["unet"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(ALL_MODELS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_root = pathlib.Path(args.out)
    out_root.mkdir(parents=True, exist_ok=True)
    manifest_path = out_root / "manifest.json"
    # always merge into the existing manifest: --force re-lowers the
    # selected models' HLO but must never drop other models' entries.
    manifest = {"version": 1, "models": {}}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())
        manifest.setdefault("models", {})

    for name in args.models.split(","):
        model = get_model(name)
        es = build_entries(model)
        mdir = out_root / name
        mdir.mkdir(exist_ok=True)
        entry_manifests = {}
        for ename, (fn, in_specs, in_names, out_names) in es.entries.items():
            path = mdir / f"{ename}.hlo.txt"
            t0 = time.time()
            lowered = jax.jit(fn).lower(*in_specs)
            out_specs = jax.eval_shape(fn, *in_specs)
            if not isinstance(out_specs, tuple):
                out_specs = (out_specs,)
            if not path.exists() or args.force:
                path.write_text(to_hlo_text(lowered))
                status = f"lowered in {time.time() - t0:.1f}s"
            else:
                status = "cached"
            entry_manifests[ename] = {
                "file": f"{name}/{ename}.hlo.txt",
                "inputs": _io_manifest(in_specs, in_names),
                "outputs": _io_manifest(list(out_specs), out_names),
            }
            print(f"[aot] {name}/{ename}: {status}")
        manifest["models"][name] = model_manifest(model, entry_manifests)
        manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"[aot] manifest -> {manifest_path}")


if __name__ == "__main__":
    main()
