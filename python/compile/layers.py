"""Layer primitives and the flat-parameter layout.

The Rust coordinator owns model state as a single flat f32 vector; every L2
program takes/returns that vector. ParamLayout assigns each named tensor a
(offset, size) slab and is serialized into manifest.json so the Rust side
can address blocks (for FIT metrics, quantization analysis and reporting)
without knowing the model structure.

All forwards are NHWC; conv kernels are HWIO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One named parameter tensor inside the flat vector."""

    name: str
    shape: tuple[int, ...]
    offset: int
    kind: str  # "conv_w" | "fc_w" | "bias" | "bn_gamma" | "bn_beta"
    block: int  # quantizable weight-block index, or -1

    @property
    def size(self) -> int:
        return math.prod(self.shape)


class ParamLayout:
    """Fixed-order flattening of named tensors into one f32 vector."""

    def __init__(self) -> None:
        self.specs: list[TensorSpec] = []
        self._by_name: dict[str, TensorSpec] = {}
        self.n_params = 0

    def add(self, name: str, shape: tuple[int, ...], kind: str, block: int = -1) -> TensorSpec:
        spec = TensorSpec(name, tuple(shape), self.n_params, kind, block)
        self.specs.append(spec)
        self._by_name[name] = spec
        self.n_params += spec.size
        return spec

    def get(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        s = self._by_name[name]
        return jax.lax.dynamic_slice(flat, (s.offset,), (s.size,)).reshape(s.shape)

    def slab(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        """Flat (size,) view of a named tensor."""
        s = self._by_name[name]
        return jax.lax.dynamic_slice(flat, (s.offset,), (s.size,))

    def spec(self, name: str) -> TensorSpec:
        return self._by_name[name]

    def to_manifest(self) -> list[dict]:
        return [
            {
                "name": s.name,
                "shape": list(s.shape),
                "offset": s.offset,
                "size": s.size,
                "kind": s.kind,
                "block": s.block,
            }
            for s in self.specs
        ]


# ------------------------------------------------------------------ init


def _fan_in(shape: tuple[int, ...], kind: str) -> int:
    if kind == "conv_w":  # HWIO
        return shape[0] * shape[1] * shape[2]
    if kind == "fc_w":  # (in, out)
        return shape[0]
    return 1


def init_flat(layout: ParamLayout, seed: jnp.ndarray) -> jnp.ndarray:
    """He-normal weights, zero biases, unit gammas — from a u32 seed."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    parts = []
    for i, s in enumerate(layout.specs):
        if s.kind in ("conv_w", "fc_w"):
            k = jax.random.fold_in(key, i)
            std = math.sqrt(2.0 / _fan_in(s.shape, s.kind))
            parts.append(jax.random.normal(k, (s.size,), jnp.float32) * std)
        elif s.kind == "bn_gamma":
            parts.append(jnp.ones((s.size,), jnp.float32))
        else:
            parts.append(jnp.zeros((s.size,), jnp.float32))
    return jnp.concatenate(parts)


# ------------------------------------------------------------- primitives


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """SAME-padded NHWC conv with HWIO kernel plus bias."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def batch_norm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Batch-statistics normalization over (N, H, W) per channel."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return gamma * (x - mean) * jax.lax.rsqrt(var + eps) + beta


def max_pool(x: jnp.ndarray, window: int = 2) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, window, window, 1),
        padding="VALID",
    )


def upsample2(x: jnp.ndarray) -> jnp.ndarray:
    """Nearest-neighbour 2x upsampling (U-Net decoder)."""
    b, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (b, h, 2, w, 2, c))
    return x.reshape(b, h * 2, w * 2, c)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x @ w + b


# ----------------------------------------------------------------- losses


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example cross entropy. logits (..., C), labels (...) int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return logz - gold


def accuracy_counts(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked correct-prediction count (classification eval)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum(mask * (pred == labels.astype(jnp.int32)).astype(jnp.float32))


def iou_counts(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray, n_classes: int):
    """Per-class (intersection, union) pixel counts for mIoU (segmentation).

    logits (B, H, W, C); labels (B, H, W) int; mask (B,) sample weights.
    """
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    labels = labels.astype(jnp.int32)
    m = mask[:, None, None]
    inter, union = [], []
    for c in range(n_classes):
        p = (pred == c).astype(jnp.float32) * m
        t = (labels == c).astype(jnp.float32) * m
        i = jnp.sum(p * t)
        inter.append(i)
        union.append(jnp.sum(p) + jnp.sum(t) - i)
    return jnp.stack(inter), jnp.stack(union)


# ------------------------------------------------------------------ adam


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-2
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def adam_update(cfg: AdamConfig, grads, params, m, v, step):
    """One Adam step on flat vectors. step is the 1-based f32 step count."""
    m = cfg.b1 * m + (1.0 - cfg.b1) * grads
    v = cfg.b2 * v + (1.0 - cfg.b2) * grads * grads
    # bias correction with runtime step
    c1 = 1.0 - jnp.power(cfg.b1, step)
    c2 = 1.0 - jnp.power(cfg.b2, step)
    mhat = m / c1
    vhat = v / c2
    params = params - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
    return params, m, v


Apply = Callable[..., jnp.ndarray]
