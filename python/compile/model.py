"""The paper's model zoo (Fig. 8 convolutional classifier + scaled variants).

Every model is exposed as a `Model`: a flat-parameter layout plus a single
`apply` that supports three orthogonal modes, so the FP forward, the QAT
(fake-quant) forward and the activation-tap forward all share one code path:

- quant:    per-block fake quantization of weights (min-max ranges computed
            in-graph) and activations (calibrated ranges passed in), with
            straight-through gradients — paper Appendix A.
- act_eps:  additive zero perturbations at each activation site; gradients
            w.r.t. these are the activation gradients the activation-Fisher
            trace needs (paper §3.2.1).

Variants:
- cnn_mnist[_bn]  — Fig. 8 architecture at synmnist scale (1x16x16 in).
- cnn_cifar[_bn]  — filters scaled by 2, 3x32x32 in (paper Appendix D).
- cnn_s/m/l/xl    — width/depth-scaled stand-ins for the ImageNet backbones
                    of Table 1 / Figs 1-2 (see DESIGN.md substitutions).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import layers
from .kernels import fake_quant


@dataclasses.dataclass(frozen=True)
class QuantInputs:
    """Runtime quantization configuration (one compiled exe, all configs)."""

    bits_w: jnp.ndarray  # (Lw,) f32
    bits_a: jnp.ndarray  # (La,) f32
    act_lo: jnp.ndarray  # (La,) f32 calibrated activation ranges
    act_hi: jnp.ndarray  # (La,) f32


@jax.custom_vjp
def _ste_fake_quant(x, lo, hi, bits):
    """fake_quant with a straight-through gradient (paper Appendix A).

    custom_vjp (identity backward on x, zeros on the scalars) keeps autodiff
    from linearizing through the Pallas call — the STE *is* the derivative
    rule, exactly as in the paper's Fig. 6.
    """
    return fake_quant(x, lo, hi, bits)


def _ste_fwd(x, lo, hi, bits):
    return fake_quant(x, lo, hi, bits), None


def _ste_bwd(_res, g):
    return g, None, None, None


_ste_fake_quant.defvjp(_ste_fwd, _ste_bwd)


def ste_quant_weight(w: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Min-max fake-quantize a weight tensor with a straight-through grad."""
    lo = jax.lax.stop_gradient(jnp.min(w))
    hi = jax.lax.stop_gradient(jnp.max(w))
    return _ste_fake_quant(w, lo, hi, bits)


def ste_quant_act(a: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    return _ste_fake_quant(a, lo, hi, bits)


@dataclasses.dataclass
class Model:
    """A flat-parameter model plus block metadata for the manifest."""

    name: str
    layout: layers.ParamLayout
    input_shape: tuple[int, int, int]  # (H, W, C)
    n_classes: int
    task: str  # "classify" | "segment"
    weight_block_names: list[str]  # tensor name per quantizable block
    act_shapes: list[tuple[int, ...]]  # per-sample activation shapes
    apply: Callable  # (flat, x, quant=None, act_eps=None) -> logits

    @property
    def n_params(self) -> int:
        return self.layout.n_params

    @property
    def n_weight_blocks(self) -> int:
        return len(self.weight_block_names)

    @property
    def n_act_blocks(self) -> int:
        return len(self.act_shapes)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    input_shape: tuple[int, int, int]
    filters: tuple[int, ...]  # one conv per entry
    n_classes: int = 10
    batch_norm: bool = False
    pool_after: tuple[int, ...] = (0, 1)  # pool after conv i (0-based)


def build_cnn(cfg: CNNConfig) -> Model:
    layout = layers.ParamLayout()
    h, w, cin = cfg.input_shape
    block = 0
    weight_block_names: list[str] = []
    act_shapes: list[tuple[int, ...]] = []

    # -- declare parameters in forward order
    c_prev = cin
    hw = (h, w)
    for i, c_out in enumerate(cfg.filters):
        layout.add(f"conv{i}.w", (3, 3, c_prev, c_out), "conv_w", block)
        weight_block_names.append(f"conv{i}.w")
        block += 1
        layout.add(f"conv{i}.b", (c_out,), "bias")
        if cfg.batch_norm:
            layout.add(f"conv{i}.gamma", (c_out,), "bn_gamma")
            layout.add(f"conv{i}.beta", (c_out,), "bn_beta")
        act_shapes.append((hw[0], hw[1], c_out))
        if i in cfg.pool_after:
            hw = (hw[0] // 2, hw[1] // 2)
        c_prev = c_out
    feat = hw[0] * hw[1] * c_prev
    layout.add("fc.w", (feat, cfg.n_classes), "fc_w", block)
    weight_block_names.append("fc.w")
    layout.add("fc.b", (cfg.n_classes,), "bias")

    def apply(flat, x, quant: QuantInputs | None = None, act_eps=None, collect=None):
        a = x
        act_idx = 0
        for i, _c_out in enumerate(cfg.filters):
            wt = layout.get(flat, f"conv{i}.w")
            if quant is not None:
                wt = ste_quant_weight(wt, quant.bits_w[i])
            a = layers.conv2d(a, wt, layout.get(flat, f"conv{i}.b"))
            if cfg.batch_norm:
                a = layers.batch_norm(
                    a,
                    layout.get(flat, f"conv{i}.gamma"),
                    layout.get(flat, f"conv{i}.beta"),
                )
            a = jax.nn.relu(a)
            if act_eps is not None:
                a = a + act_eps[act_idx]
            if collect is not None:
                collect.append(a)
            if quant is not None:
                a = ste_quant_act(
                    a, quant.act_lo[act_idx], quant.act_hi[act_idx], quant.bits_a[act_idx]
                )
            act_idx += 1
            if i in cfg.pool_after:
                a = layers.max_pool(a)
        a = a.reshape(a.shape[0], -1)
        wt = layout.get(flat, "fc.w")
        if quant is not None:
            wt = ste_quant_weight(wt, quant.bits_w[len(cfg.filters)])
        logits = layers.dense(a, wt, layout.get(flat, "fc.b"))
        return logits

    return Model(
        name=cfg.name,
        layout=layout,
        input_shape=cfg.input_shape,
        n_classes=cfg.n_classes,
        task="classify",
        weight_block_names=weight_block_names,
        act_shapes=act_shapes,
        apply=apply,
    )


# ----------------------------------------------------------------- registry

CNN_CONFIGS: dict[str, CNNConfig] = {
    # Table-2 / Fig-3 study models (paper Appendix D, Fig 8).
    "cnn_mnist": CNNConfig("cnn_mnist", (16, 16, 1), (8, 16, 16)),
    "cnn_mnist_bn": CNNConfig("cnn_mnist_bn", (16, 16, 1), (8, 16, 16), batch_norm=True),
    "cnn_cifar": CNNConfig("cnn_cifar", (32, 32, 3), (16, 32, 32)),
    "cnn_cifar_bn": CNNConfig("cnn_cifar_bn", (32, 32, 3), (16, 32, 32), batch_norm=True),
    # Table-1 / Fig-1/2/7 scale ladder (ImageNet-backbone stand-ins).
    # 16x16 input keeps single-core CPU-PJRT iteration times in the regime
    # where hundreds of estimator iterations are affordable; the ladder
    # spans ~23x in parameter count and 4..6 blocks in depth.
    "cnn_s": CNNConfig("cnn_s", (16, 16, 3), (8, 16, 16)),
    "cnn_m": CNNConfig("cnn_m", (16, 16, 3), (16, 32, 32)),
    "cnn_l": CNNConfig("cnn_l", (16, 16, 3), (32, 64, 64, 64), pool_after=(0, 1, 2)),
    "cnn_xl": CNNConfig(
        "cnn_xl", (16, 16, 3), (48, 96, 96, 96, 96), pool_after=(0, 1, 2)
    ),
}


def get_model(name: str) -> Model:
    if name in CNN_CONFIGS:
        return build_cnn(CNN_CONFIGS[name])
    if name == "unet":
        from .unet import build_unet

        return build_unet()
    raise KeyError(f"unknown model {name!r}")


STUDY_MODELS: Sequence[str] = ("cnn_mnist", "cnn_mnist_bn", "cnn_cifar", "cnn_cifar_bn")
SCALE_MODELS: Sequence[str] = ("cnn_s", "cnn_m", "cnn_l", "cnn_xl")
