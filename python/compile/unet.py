"""Small U-Net for the segmentation study (paper §4.3, Fig. 4).

Encoder-decoder with skip connections: two down levels, a bottleneck, two up
levels and a 1x1 classifier head — the same topology as Ronneberger et al.
scaled to the synthetic 32x32 shapes-segmentation dataset. Eleven
quantizable weight blocks, nine activation sites.

Shares the `Model` interface (quant / act_eps modes) with the CNNs so every
L2 program (train, QAT, EF trace, ranges) is model-agnostic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .model import Model, QuantInputs, ste_quant_act, ste_quant_weight

INPUT_SHAPE = (32, 32, 3)
N_CLASSES = 4
# channel widths: enc1, enc2, bottleneck, dec2, dec1
WIDTHS = (8, 16, 32, 16, 8)


def build_unet() -> Model:
    layout = layers.ParamLayout()
    h, w, cin = INPUT_SHAPE
    e1, e2, bt, d2, d1 = WIDTHS

    convs = [
        # name, cin, cout, activation spatial size
        ("enc1a", cin, e1, (h, w)),
        ("enc1b", e1, e1, (h, w)),
        ("enc2a", e1, e2, (h // 2, w // 2)),
        ("enc2b", e2, e2, (h // 2, w // 2)),
        ("bott", e2, bt, (h // 4, w // 4)),
        ("dec2a", bt + e2, d2, (h // 2, w // 2)),
        ("dec2b", d2, d2, (h // 2, w // 2)),
        ("dec1a", d2 + e1, d1, (h, w)),
        ("dec1b", d1, d1, (h, w)),
    ]

    weight_block_names: list[str] = []
    act_shapes: list[tuple[int, ...]] = []
    for b, (name, ci, co, hw) in enumerate(convs):
        layout.add(f"{name}.w", (3, 3, ci, co), "conv_w", b)
        layout.add(f"{name}.b", (co,), "bias")
        weight_block_names.append(f"{name}.w")
        act_shapes.append((hw[0], hw[1], co))
    layout.add("head.w", (1, 1, d1, N_CLASSES), "conv_w", len(convs))
    layout.add("head.b", (N_CLASSES,), "bias")
    weight_block_names.append("head.w")

    def apply(flat, x, quant: QuantInputs | None = None, act_eps=None, collect=None):
        idx = [0]

        def conv_relu(a, name):
            i = idx[0]
            wt = layout.get(flat, f"{name}.w")
            if quant is not None:
                wt = ste_quant_weight(wt, quant.bits_w[i])
            a = layers.conv2d(a, wt, layout.get(flat, f"{name}.b"))
            a = jax.nn.relu(a)
            if act_eps is not None:
                a = a + act_eps[i]
            if collect is not None:
                collect.append(a)
            if quant is not None:
                a = ste_quant_act(a, quant.act_lo[i], quant.act_hi[i], quant.bits_a[i])
            idx[0] = i + 1
            return a

        s1 = conv_relu(conv_relu(x, "enc1a"), "enc1b")
        p1 = layers.max_pool(s1)
        s2 = conv_relu(conv_relu(p1, "enc2a"), "enc2b")
        p2 = layers.max_pool(s2)
        b = conv_relu(p2, "bott")
        u2 = jnp.concatenate([layers.upsample2(b), s2], axis=-1)
        d2_ = conv_relu(conv_relu(u2, "dec2a"), "dec2b")
        u1 = jnp.concatenate([layers.upsample2(d2_), s1], axis=-1)
        d1_ = conv_relu(conv_relu(u1, "dec1a"), "dec1b")
        wt = layout.get(flat, "head.w")
        if quant is not None:
            wt = ste_quant_weight(wt, quant.bits_w[len(convs)])
        logits = layers.conv2d(d1_, wt, layout.get(flat, "head.b"))
        return logits  # (B, H, W, N_CLASSES)

    return Model(
        name="unet",
        layout=layout,
        input_shape=INPUT_SHAPE,
        n_classes=N_CLASSES,
        task="segment",
        weight_block_names=weight_block_names,
        act_shapes=act_shapes,
        apply=apply,
    )
